"""Serving engine tests: lifecycle, continuous waves, enc-dec context."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.nn import transformer as T
from repro.serve.engine import Request, ServeEngine


def _engine(name="qwen3-0.6b", batch=2, max_len=32):
    cfg = ARCHS[name].reduced(vocab_size=64)
    params = T.init(jax.random.PRNGKey(0), cfg)
    return ServeEngine(params, cfg, batch=batch, max_len=max_len), cfg


def test_requests_complete_with_outputs():
    eng, cfg = _engine()
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new_tokens=4)
            for i in range(5)]
    out = eng.run(reqs)
    assert all(r.done for r in out)
    assert all(len(r.output) == 4 for r in out)
    assert all(0 <= t < cfg.vocab_size for r in out for t in r.output)
    assert eng.stats.requests_completed == 5
    assert eng.stats.tokens_generated == 20


def test_greedy_decode_is_deterministic():
    eng1, _ = _engine()
    eng2, _ = _engine()
    r1 = eng1.run([Request(0, [5, 6, 7], max_new_tokens=6)])[0]
    r2 = eng2.run([Request(0, [5, 6, 7], max_new_tokens=6)])[0]
    assert r1.output == r2.output


def test_eos_stops_generation():
    eng, cfg = _engine()
    probe = eng.run([Request(0, [3, 4], max_new_tokens=8)])[0]
    eos = probe.output[1] if len(probe.output) > 1 else probe.output[0]
    eng2, _ = _engine()
    r = eng2.run([Request(0, [3, 4], max_new_tokens=8, eos_id=eos)])[0]
    assert r.done
    assert len(r.output) <= len(probe.output)


def test_hybrid_arch_serving():
    eng, _ = _engine("recurrentgemma-9b")
    out = eng.run([Request(0, [1, 2], max_new_tokens=3)])
    assert len(out[0].output) == 3


def test_ssm_arch_serving():
    eng, _ = _engine("falcon-mamba-7b")
    out = eng.run([Request(0, [1, 2, 3, 4], max_new_tokens=3)])
    assert len(out[0].output) == 3


def test_encdec_serving_with_context():
    cfg = ARCHS["whisper-base"].reduced(vocab_size=64)
    params = T.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, batch=2, max_len=32)
    frames = jax.random.normal(
        jax.random.PRNGKey(1), (2, cfg.encoder.num_frames, cfg.d_model))
    enc_out = T._encoder_forward(params["encoder"], frames, cfg, remat=False)
    out = eng.run([Request(0, [1], max_new_tokens=3)], enc_out=enc_out)
    assert len(out[0].output) == 3
