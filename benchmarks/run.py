"""Benchmark harness: one function per paper table/figure + framework
benches. Prints ``name,us_per_call,derived`` CSV summaries at the end.

  fig1_standalone — paper Fig. 1 (standalone technique Pareto fronts)
  fig2_combined   — paper Fig. 2 (hardware-aware GA, combined techniques)
  area_table      — paper §III baseline circuit table
  kernel_bench    — per-kernel derived TPU roofline
  roofline_table  — §Roofline across all dry-run cells
  ga_bench        — GA hot path: serial vs batched population evaluation
  circuit_bench   — bespoke netlist compile / bit-exact sim / delay
  approx_bench    — budgeted circuit approximation + approximation-GA
  search_bench    — island runtime: throughput / checkpoint / resume cost

``python -m benchmarks.run [--fast] [--only NAME]``
"""
from __future__ import annotations

import argparse
import time

from benchmarks import approx_bench, area_table, circuit_bench, \
    dryrun_memory_table, fig1_standalone, fig2_combined, ga_bench, \
    kernel_bench, roofline_table, search_bench

BENCHES = [
    ("area_table", area_table.main),
    ("fig1_standalone", fig1_standalone.main),
    ("fig2_combined", fig2_combined.main),
    ("kernel_bench", kernel_bench.main),
    ("roofline_table", roofline_table.main),
    ("dryrun_memory_table", dryrun_memory_table.main),
    ("ga_bench", ga_bench.main),
    ("circuit_bench", circuit_bench.main),
    ("approx_bench", approx_bench.main),
    ("search_bench", search_bench.main),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    csv = []
    for name, fn in BENCHES:
        if args.only and name != args.only:
            continue
        print(f"\n=== {name} {'=' * (60 - len(name))}")
        t0 = time.time()
        fn(fast=args.fast)
        us = (time.time() - t0) * 1e6
        csv.append(f"{name},{us:.0f},see-above")
    print("\nname,us_per_call,derived")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
