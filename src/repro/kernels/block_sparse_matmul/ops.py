"""jit'd wrapper (shapes must already be block multiples — pruning masks are
built on padded weights by `repro.core.pruning.block_mask`)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.block_sparse_matmul.kernel import block_sparse_matmul_pallas
from repro.kernels.block_sparse_matmul.ref import block_sparse_matmul_ref


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def block_sparse_matmul(x, w, block_mask, *, block_m=128, block_n=128,
                        block_k=128, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return block_sparse_matmul_pallas(
        x, w, block_mask, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret)


__all__ = ["block_sparse_matmul", "block_sparse_matmul_ref"]
