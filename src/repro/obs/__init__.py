"""Search-runtime observability: ambient tracing, metrics, run reports.

Three pieces, all zero-cost when off (the ``REPRO_TRACE`` idiom, mirroring
``REPRO_VERIFY``):

* `repro.obs.trace` — nestable host-side spans and structured events,
  appended as torn-write-safe JSONL;
* `repro.obs.metrics` — the process-wide counter/gauge/histogram registry,
  snapshotted into every search checkpoint and restored bit-identically on
  resume;
* `repro.obs.prof` / `repro.obs.xprof` — the executable observatory:
  a process-wide registry of jit executables (cost/memory analysis on
  first compile, compile-event accounting via ``jax.monitoring``,
  per-key dispatch counts), snapshotted into every search checkpoint
  like the metrics registry;
* `repro.obs.report` — ``python -m repro.obs.report trace.jsonl`` renders
  wall-clock breakdowns, per-island timelines, Pareto progress, cache-hit
  curves, the executables/padding-waste sections and the fault/quarantine
  ledger (plus CSVs).

`repro.obs.ring.RingLog` is the bounded in-memory event log the search
runtime uses so long runs spill their full event stream to the trace
instead of growing lists without bound.
"""
from repro.obs import metrics, prof, xprof
from repro.obs.ring import RingLog
from repro.obs.trace import (active, capture, event, first_call, read_trace,
                             span, start, stop)

__all__ = ["RingLog", "active", "capture", "event", "first_call",
           "metrics", "prof", "read_trace", "span", "start", "stop",
           "xprof"]
