"""qwen3-0.6b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B family]"""
from repro.configs.base import ArchConfig, LayerSpec, Segment

CONFIG = ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    d_model=1024,
    vocab_size=151936,
    segments=(Segment((LayerSpec("attn", "dense"),), 28),),
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    mlp_type="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-0.6B; hf",
)
