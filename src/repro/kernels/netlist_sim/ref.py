"""NumPy oracle for the population netlist-sim kernel.

Walks every candidate's slot table in order (level-major slots are a
topological order) with exact int64 lanes — the verifier's 62-bit sim
budget guarantees int64 never overflows. Deliberately the dumbest possible
interpretation of the packed tables so kernel bugs can't be mirrored here.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.circuit import ir
from repro.kernels.netlist_sim.pack import PackedPopulation


def _normalize_x(pop: PackedPopulation, x: np.ndarray) -> np.ndarray:
    """Accept (B, n_in) shared inputs or (P, B, n_in) per-candidate inputs
    (candidates may quantize the ADC lanes at different input_bits) ->
    (P, B, n_in) int64."""
    x = np.asarray(x)
    if x.ndim == 2:
        x = np.broadcast_to(x[None], (pop.n_candidates,) + x.shape)
    if x.shape[0] != pop.n_candidates or x.shape[2] != pop.n_inputs:
        raise ValueError(f"x shape {x.shape} vs population "
                         f"(P={pop.n_candidates}, n_in={pop.n_inputs})")
    return x.astype(np.int64)


def simulate_population_ref(pop: PackedPopulation, x: np.ndarray
                            ) -> Dict[str, np.ndarray]:
    """-> {"amx": (P, B, C) int64 comparator operands,
           "argmax": (P, B) int64 class decisions}."""
    x = _normalize_x(pop, x)
    P, B = x.shape[0], x.shape[1]
    C = pop.n_classes
    amx = np.zeros((P, B, C), np.int64)
    for p in range(P):
        n = int(pop.n_nodes[p])
        vals = np.zeros((B, n), np.int64)
        vals[:, pop.input_pos[p]] = x[p]
        for s in range(n):
            o = int(pop.op[p, s])
            if o == int(ir.Op.CONST):
                vals[:, s] = pop.val[p, s]
            elif o in (int(ir.Op.INPUT), int(ir.Op.ARGMAX)):
                continue
            else:
                a = vals[:, pop.arg_a[p, s]]
                k = int(pop.shift[p, s])
                if o == int(ir.Op.SHL):
                    vals[:, s] = a << k
                elif o == int(ir.Op.TRUNC):
                    vals[:, s] = (a >> k) << k
                elif o == int(ir.Op.ADD):
                    vals[:, s] = a + vals[:, pop.arg_b[p, s]]
                elif o == int(ir.Op.SUB):
                    vals[:, s] = a - vals[:, pop.arg_b[p, s]]
                elif o == int(ir.Op.NEG):
                    vals[:, s] = -a
                elif o == int(ir.Op.RELU):
                    vals[:, s] = np.maximum(a, 0)
                else:
                    raise ValueError(f"bad opcode {o} at slot {s}")
        amx[p] = vals[:, pop.argmax_pos[p]]
    return {"amx": amx, "argmax": np.argmax(amx, axis=-1).astype(np.int64)}
