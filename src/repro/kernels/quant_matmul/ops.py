"""jit'd wrapper: padding to block multiples + CPU interpret fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quant_matmul.kernel import quant_matmul_pallas
from repro.kernels.quant_matmul.ref import quant_matmul_ref


def _pad_to(a, mult, axis):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def quant_matmul(x, w_q, scales, *, block_m=128, block_n=128, block_k=128,
                 interpret: bool | None = None):
    """y = x @ dequant(w_q, scales). Shapes padded to block multiples; the
    kernel runs interpret=True off-TPU (correctness path on this container)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    M, N = x.shape[0], w_q.shape[1]
    xp = _pad_to(_pad_to(x, block_m, 0), block_k, 1)
    wp = _pad_to(_pad_to(w_q, block_k, 0), block_n, 1)
    sp = _pad_to(scales, block_n, 0)
    y = quant_matmul_pallas(xp, wp, sp, block_m=block_m, block_n=block_n,
                            block_k=block_k, interpret=interpret)
    return y[:M, :N]


__all__ = ["quant_matmul", "quant_matmul_ref"]
