"""llama-3.2-vision-11b [vlm] — decoder with gated cross-attention image
layers every 5th layer; vision tower is a STUB (input_specs feeds precomputed
patch embeddings). [hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.configs.base import ArchConfig, LayerSpec, Segment, VisionConfig

_PERIOD = (
    LayerSpec("attn", "dense"),
    LayerSpec("attn", "dense"),
    LayerSpec("attn", "dense"),
    LayerSpec("cross", "dense"),       # gated cross-attn to patch embeddings
    LayerSpec("attn", "dense"),
)

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    d_model=4096,
    vocab_size=128256,
    segments=(Segment(_PERIOD, 8),),   # 40 layers, 8 cross-attn
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    mlp_type="swiglu",
    rope_theta=500000.0,
    vision=VisionConfig(num_patches=1601),
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
