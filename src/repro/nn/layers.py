"""Primitive layers: dense, norms, embeddings, activations, RoPE."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _trunc_normal(key, shape, std, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16, *,
               bias: bool = False, out_shape=None):
    """Dense kernel. ``out_shape`` reshapes the output dim (e.g. (H, hd)) so
    sharding rules see the head axis explicitly."""
    std = 1.0 / math.sqrt(d_in)
    shape = (d_in,) + tuple(out_shape) if out_shape else (d_in, d_out)
    p = {"kernel": _trunc_normal(key, shape, std, dtype)}
    if bias:
        p["bias"] = jnp.zeros(shape[1:], dtype)
    return p


def dense_apply(p, x):
    k = p["kernel"]
    if k.ndim == 2:
        y = jnp.einsum("...d,df->...f", x, k)
    elif k.ndim == 3:  # (d, H, hd)
        y = jnp.einsum("...d,dhf->...hf", x, k)
    else:
        raise ValueError(k.shape)
    if "bias" in p:
        y = y + p["bias"]
    return y


def dense_in3_apply(p, x):
    """Contract a (H, hd, d) kernel against (..., H, hd) input."""
    y = jnp.einsum("...hf,hfd->...d", x, p["kernel"])
    if "bias" in p:
        y = y + p["bias"]
    return y


def dense_in3_init(key, h: int, hd: int, d_out: int, dtype=jnp.bfloat16,
                   bias: bool = False):
    std = 1.0 / math.sqrt(h * hd)
    p = {"kernel": _trunc_normal(key, (h, hd, d_out), std, dtype)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_init(d: int, norm_type: str = "rmsnorm", dtype=jnp.float32):
    if norm_type == "rmsnorm":
        # zero-centered scale, ALWAYS applied as (1 + scale): gemma's (1+w)
        # and the plain w-with-ones-init parameterizations are identical up
        # to this storage convention, so one convention serves every arch.
        return {"scale": jnp.zeros((d,), dtype)}
    if norm_type == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    raise ValueError(norm_type)


def norm_apply(p, x, norm_type: str = "rmsnorm", *, unit_offset: bool = True,
               eps: float = 1e-6):
    # unit_offset kept for API stability; rmsnorm is always (1 + scale)
    del unit_offset
    xf = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        y = y * (1.0 + p["scale"].astype(jnp.float32))
    elif norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        raise ValueError(norm_type)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------


def embedding_init(key, vocab: int, d: int, dtype=jnp.bfloat16):
    return {"table": _trunc_normal(key, (vocab, d), 1.0, dtype)}


def embedding_apply(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def positional_init(key, max_pos: int, d: int, dtype=jnp.bfloat16):
    return {"table": _trunc_normal(key, (max_pos, d), 0.02, dtype)}


# ---------------------------------------------------------------------------
# activations / MLP variants
# ---------------------------------------------------------------------------


def squared_relu(x):
    return jnp.square(jax.nn.relu(x))


def mlp_init(key, d: int, d_ff: int, mlp_type: str, dtype=jnp.bfloat16,
             *, bias: bool = False):
    k1, k2, k3 = jax.random.split(key, 3)
    if mlp_type in ("swiglu", "geglu"):
        return {
            "wi_gate": dense_init(k1, d, d_ff, dtype, bias=bias),
            "wi_up": dense_init(k2, d, d_ff, dtype, bias=bias),
            "wo": dense_init(k3, d_ff, d, dtype, bias=bias),
        }
    if mlp_type in ("relu2", "gelu"):
        return {
            "wi": dense_init(k1, d, d_ff, dtype, bias=bias),
            "wo": dense_init(k2, d_ff, d, dtype, bias=bias),
        }
    raise ValueError(mlp_type)


def mlp_apply(p, x, mlp_type: str):
    if mlp_type == "swiglu":
        h = jax.nn.silu(dense_apply(p["wi_gate"], x)) * dense_apply(p["wi_up"], x)
        return dense_apply(p["wo"], h)
    if mlp_type == "geglu":
        h = jax.nn.gelu(dense_apply(p["wi_gate"], x), approximate=True) \
            * dense_apply(p["wi_up"], x)
        return dense_apply(p["wo"], h)
    if mlp_type == "relu2":
        return dense_apply(p["wo"], squared_relu(dense_apply(p["wi"], x)))
    if mlp_type == "gelu":
        return dense_apply(p["wo"],
                           jax.nn.gelu(dense_apply(p["wi"], x), approximate=True))
    raise ValueError(mlp_type)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., T, hd/2)
    sin = jnp.sin(angles)[..., None, :]                # (..., T, 1, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap else x
