"""Synthetic LM token pipeline: deterministic, stateless (step -> batch), so
training restarts reproduce the exact data order (fault tolerance without a
data-loader checkpoint).

The stream is a seeded order-2 Markov chain over the vocab — enough structure
for the 100M-model example to show a real falling loss curve (the model can
learn the transition table), unlike uniform noise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 8       # out-degree of the Markov chain


class TokenPipeline:
    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # sparse deterministic transition structure
        self._succ = rng.integers(
            0, cfg.vocab_size,
            size=(min(cfg.vocab_size, 65536), cfg.branching)).astype(np.int32)

    def batch_at(self, step: int, *, host_id: int = 0,
                 n_hosts: int = 1) -> Dict[str, np.ndarray]:
        """Deterministic batch for `step`; hosts draw disjoint slices of the
        global batch (host-local loading at scale)."""
        cfg = self.cfg
        assert cfg.global_batch % n_hosts == 0
        local = cfg.global_batch // n_hosts
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 131 + host_id)
        toks = np.empty((local, cfg.seq_len), np.int32)
        state = rng.integers(0, self._succ.shape[0], size=local)
        toks[:, 0] = state
        for t in range(1, cfg.seq_len):
            choice = rng.integers(0, cfg.branching, size=local)
            state = self._succ[state % self._succ.shape[0], choice]
            toks[:, t] = state
        return {"tokens": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
