"""Batched population engine: equivalence with the serial path, vectorized
CSD pricing, and the persistent evaluation cache."""
import numpy as np
import pytest

from repro.configs.printed_mlp import PRINTED_MLPS
from repro.core import batch_eval as BE
from repro.core import hw_model as HW
from repro.core import minimize as MZ
from repro.core.compression_spec import LayerMin, ModelMin

CFG = PRINTED_MLPS["seeds"]          # smallest dataset: fastest finetunes
N_LAYERS = len(CFG.layer_dims) - 1

# a deliberately heterogeneous population: off/on per technique, mixed
# bits/sparsity/cluster counts, including the all-off baseline gene
SPECS = [
    ModelMin.uniform(N_LAYERS, bits=8),
    ModelMin.uniform(N_LAYERS, bits=3),
    ModelMin.uniform(N_LAYERS, bits=6, sparsity=0.4),
    ModelMin.uniform(N_LAYERS, bits=4, sparsity=0.3, clusters=4),
    ModelMin((LayerMin(2, 0.5, 2), LayerMin(8, 0.0, 16)), 8),
    ModelMin((LayerMin(5, 0.0, 3), LayerMin(4, 0.2, None)), 8),
]


# ---------------------------------------------------------------------------
# vectorized CSD / pricing
# ---------------------------------------------------------------------------


def test_csd_vec_matches_scalar_for_all_int8():
    coeffs = np.arange(-128, 128)
    vec = HW.csd_nonzero_digits_vec(coeffs)
    ref = np.array([HW.csd_nonzero_digits(int(c)) for c in coeffs])
    np.testing.assert_array_equal(vec, ref)


def test_csd_vec_wide_range_and_shapes():
    rng = np.random.default_rng(0)
    q = rng.integers(-(2 ** 15), 2 ** 15, (7, 11, 13))
    vec = HW.csd_nonzero_digits_vec(q)
    ref = np.array([HW.csd_nonzero_digits(int(c)) for c in q.reshape(-1)])
    np.testing.assert_array_equal(vec.reshape(-1), ref)


def test_mlp_cost_batch_matches_scalar_per_candidate():
    rng = np.random.default_rng(1)
    P_ = 5
    qs, bits, cls = [], [], []
    for (din, dout) in [(7, 8), (8, 3)]:
        q = rng.integers(-127, 128, (P_, din, dout))
        q[rng.random(q.shape) < 0.35] = 0
        idx = rng.integers(0, 4, (P_, din, dout))
        cb = rng.integers(-127, 128, (P_, din, 4))
        has = np.array([True, False, True, False, True])
        qs.append(q)
        bits.append(rng.integers(2, 9, P_))
        cls.append((idx, cb, has))
    batch = HW.mlp_cost_batch(qs, w_bits=bits, clusters=cls)
    for p in range(P_):
        clp = [(cls[i][0][p], cls[i][1][p]) if cls[i][2][p] else None
               for i in range(2)]
        ref = HW.mlp_cost([q[p] for q in qs],
                          w_bits=[int(b[p]) for b in bits], clusters=clp)
        assert batch["total_fa"][p] == ref.total_fa
        assert batch["n_multipliers"][p] == ref.n_multipliers
        assert batch["area_mm2"][p] == ref.area_mm2


# ---------------------------------------------------------------------------
# batched vs serial evaluation (the tentpole equivalence)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serial_and_batched():
    serial = [MZ.evaluate_spec(CFG, s, epochs=30) for s in SPECS]
    batched = BE.evaluate_population(CFG, SPECS, epochs=30)
    return serial, batched


def test_batched_objectives_match_serial(serial_and_batched):
    serial, batched = serial_and_batched
    for s, b in zip(serial, batched):
        assert abs(s.accuracy - b.accuracy) <= 1e-3, s.spec
        assert abs(s.area_mm2 - b.area_mm2) <= 1e-3 * max(s.area_mm2, 1.0)
        assert s.n_multipliers == b.n_multipliers, s.spec
        assert abs(s.power_mw - b.power_mw) <= 1e-3 * max(s.power_mw, 1.0)


def test_batched_prices_mixed_input_bits_per_candidate():
    """Regression: a population mixing input_bits must price each candidate
    at its own input width (prod_width = in_bits + w_bits drives every cost
    term), matching serial evaluate_spec."""
    mixed = [ModelMin.uniform(N_LAYERS, bits=4, input_bits=4),
             ModelMin.uniform(N_LAYERS, bits=4, input_bits=8)]
    serial = [MZ.evaluate_spec(CFG, s, epochs=10) for s in mixed]
    batched = BE.evaluate_population(CFG, mixed, epochs=10)
    for s, b in zip(serial, batched):
        assert s.area_mm2 == b.area_mm2, s.spec
        assert s.power_mw == b.power_mw, s.spec
        assert abs(s.accuracy - b.accuracy) <= 1e-3, s.spec


def test_batched_preserves_order_and_dedups(serial_and_batched):
    _, batched = serial_and_batched
    # duplicated spec evaluates once but appears at both positions
    dup = [SPECS[1], SPECS[0], SPECS[1]]
    out = BE.evaluate_population(CFG, dup, epochs=30)
    assert [r.spec for r in out] == dup
    assert out[0].accuracy == out[2].accuracy


def test_padded_kmeans_matches_static_k():
    """Valid-slot centroids/assignments of the padded dynamic-k k-means
    equal clustering's static-k path (the equivalence the engine rests on)."""
    import jax
    import jax.numpy as jnp
    from repro.core import clustering as C
    x = jax.random.normal(jax.random.PRNGKey(3), (40,))
    # every k the GA can emit (CLUSTER_CHOICES starts at 2; 0 bypasses the
    # cluster transform entirely, 1 never occurs)
    for k in (2, 3, 5, 8, 16):
        cent_ref, a_ref = C._kmeans_1d(x, k)
        cent, a = BE._padded_kmeans_1d(x, jnp.int32(k), BE.K_MAX)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(a_ref))
        np.testing.assert_allclose(np.asarray(cent[:k]),
                                   np.asarray(cent_ref), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# persistent cache
# ---------------------------------------------------------------------------


def test_eval_cache_roundtrip(tmp_path):
    cache = BE.EvalCache(tmp_path / "evals.json")
    r = MZ.EvalResult(SPECS[3], 0.912, 1234.5, 6.7, 89)
    cache.put(CFG.name, 0, 30, r)
    cache.flush()

    fresh = BE.EvalCache(tmp_path / "evals.json")   # re-read from disk
    assert len(fresh) == 1
    hit = fresh.get(CFG.name, 0, 30, SPECS[3])
    assert hit is not None
    assert hit.spec == SPECS[3]
    assert hit.accuracy == pytest.approx(0.912)
    assert hit.area_mm2 == pytest.approx(1234.5)
    assert hit.n_multipliers == 89
    # different seed / epochs / spec are misses
    assert fresh.get(CFG.name, 1, 30, SPECS[3]) is None
    assert fresh.get(CFG.name, 0, 31, SPECS[3]) is None
    assert fresh.get(CFG.name, 0, 30, SPECS[0]) is None


def test_cache_two_writers_merge_on_flush(tmp_path):
    """Two processes sharing one cache file must union their entries:
    flush re-reads the on-disk JSON before the atomic replace, so a
    writer no longer clobbers what a concurrent writer published."""
    path = tmp_path / "shared.json"
    a = BE.EvalCache(path)
    b = BE.EvalCache(path)              # opened before a writes anything
    a.put(CFG.name, 0, 30, MZ.EvalResult(SPECS[0], 0.9, 100.0, 1.0, 10,
                                         delay_levels=15))
    a.flush()
    b.put(CFG.name, 0, 30, MZ.EvalResult(SPECS[1], 0.8, 50.0, 0.5, 5,
                                         delay_levels=12))
    b.flush()                           # must not drop a's entry

    merged = BE.EvalCache(path)
    assert len(merged) == 2
    assert merged.get(CFG.name, 0, 30, SPECS[0]).area_mm2 == 100.0
    assert merged.get(CFG.name, 0, 30, SPECS[1]).area_mm2 == 50.0
    # on a key conflict the flushing writer's (fresher) entry wins
    a2 = BE.EvalCache(path)
    a2.put(CFG.name, 0, 30, MZ.EvalResult(SPECS[0], 0.95, 99.0, 1.0, 10,
                                          delay_levels=15))
    a2.flush()
    assert BE.EvalCache(path).get(CFG.name, 0, 30,
                                  SPECS[0]).area_mm2 == 99.0


def test_cache_roundtrips_delay_and_separates_netlist_keyspace(tmp_path):
    cache = BE.EvalCache(tmp_path / "evals.json")
    r = MZ.EvalResult(SPECS[0], 0.9, 100.0, 1.0, 10, delay_levels=17)
    cache.put(CFG.name, 0, 30, r)
    cache.flush()
    hit = BE.EvalCache(tmp_path / "evals.json").get(CFG.name, 0, 30,
                                                    SPECS[0])
    assert hit.delay_levels == 17
    # netlist-exact results live under their own keys (different objective)
    assert cache.get(CFG.name, 0, 30, SPECS[0], netlist=True) is None
    cache.put(CFG.name, 0, 30,
              MZ.EvalResult(SPECS[0], 0.89, 100.0, 1.0, 10,
                            delay_levels=17), netlist=True)
    assert cache.get(CFG.name, 0, 30,
                     SPECS[0], netlist=True).accuracy == pytest.approx(0.89)
    assert cache.get(CFG.name, 0, 30,
                     SPECS[0]).accuracy == pytest.approx(0.9)


def test_cache_size_cap_evicts_lru_on_flush(tmp_path):
    """A long GA sweep must not grow the on-disk cache without bound:
    flush keeps only the ``max_entries`` most recently touched entries."""
    path = tmp_path / "capped.json"
    cache = BE.EvalCache(path, max_entries=3)
    for i, s in enumerate(SPECS[:5]):
        cache.put(CFG.name, 0, 30, MZ.EvalResult(s, 0.9, 100.0 + i, 1.0, 10))
    # refresh the OLDEST entry: a hit keeps it young through eviction
    assert cache.get(CFG.name, 0, 30, SPECS[0]) is not None
    cache.flush()

    fresh = BE.EvalCache(path, max_entries=3)
    assert len(fresh) == 3
    assert fresh.get(CFG.name, 0, 30, SPECS[0]) is not None   # refreshed
    assert fresh.get(CFG.name, 0, 30, SPECS[4]) is not None   # newest
    assert fresh.get(CFG.name, 0, 30, SPECS[1]) is None       # evicted
    assert fresh.get(CFG.name, 0, 30, SPECS[2]) is None


def test_cache_recency_only_flush_is_batched(tmp_path):
    """A warm (hit-only) flush below the touch threshold is a no-op (no
    multi-MB rewrite per cached generation); past the threshold the
    refreshed stamps do persist."""
    path = tmp_path / "warm.json"
    cache = BE.EvalCache(path)
    cache.put(CFG.name, 0, 30, MZ.EvalResult(SPECS[0], 0.9, 1.0, 1.0, 1))
    cache.flush()
    before = path.read_text()

    warm = BE.EvalCache(path)
    warm.get(CFG.name, 0, 30, SPECS[0])
    warm.flush()                          # few touches: skipped
    assert path.read_text() == before
    for _ in range(BE.EvalCache.TOUCH_FLUSH_EVERY):
        warm.get(CFG.name, 0, 30, SPECS[0])
    warm.flush()                          # batched recency persists
    assert path.read_text() != before


def test_cache_cap_survives_merge_and_uncapped_by_default(tmp_path):
    path = tmp_path / "merged.json"
    a = BE.EvalCache(path, max_entries=2)
    b = BE.EvalCache(path, max_entries=2)
    a.put(CFG.name, 0, 30, MZ.EvalResult(SPECS[0], 0.9, 1.0, 1.0, 1))
    a.flush()
    b.put(CFG.name, 0, 30, MZ.EvalResult(SPECS[1], 0.9, 2.0, 1.0, 1))
    b.put(CFG.name, 0, 30, MZ.EvalResult(SPECS[2], 0.9, 3.0, 1.0, 1))
    b.flush()                     # merge of 3 entries, capped back to 2
    assert len(BE.EvalCache(path)) == 2
    # max_entries=None disables the cap entirely
    big = BE.EvalCache(tmp_path / "uncapped.json", max_entries=None)
    for i, s in enumerate(SPECS):
        big.put(CFG.name, 0, 30, MZ.EvalResult(s, 0.9, float(i), 1.0, 1))
    big.flush()
    assert len(BE.EvalCache(tmp_path / "uncapped.json")) == len(SPECS)


def test_cache_corrupt_file_salvaged_with_backup(tmp_path):
    """A truncated cache file (crash mid-write, disk-full) must not cost
    the whole cache: `_read` backs the damaged bytes up to `.corrupt` and
    salvages every individually-parseable leading entry."""
    path = tmp_path / "evals.json"
    cache = BE.EvalCache(path)
    for i, s in enumerate(SPECS[:4]):
        cache.put(CFG.name, 0, 30, MZ.EvalResult(s, 0.9, float(i), 1.0, 1,
                                                 delay_levels=10 + i))
    cache.flush()
    whole = path.read_text()
    # tear the file mid-way through the last entry's value
    path.write_text(whole[:int(len(whole) * 0.8)])

    with pytest.warns(UserWarning, match="salvaged"):
        torn = BE.EvalCache(path)
    assert path.with_suffix(".json.corrupt").read_text() == \
        whole[:int(len(whole) * 0.8)]
    # every complete leading entry survived, the torn tail did not
    assert 1 <= len(torn) < 4
    hit = torn.get(CFG.name, 0, 30, SPECS[0])
    assert hit is not None and hit.area_mm2 == 0.0 and hit.delay_levels == 10
    # the next flush atomically rewrites a whole file again
    torn.put(CFG.name, 0, 30, MZ.EvalResult(SPECS[4], 0.9, 9.0, 1.0, 1))
    torn.flush()
    assert len(BE.EvalCache(path)) == len(torn)


def test_cache_unparseable_garbage_starts_empty(tmp_path):
    path = tmp_path / "evals.json"
    path.write_text("not json at all")
    with pytest.warns(UserWarning, match="salvaged 0 entries"):
        cache = BE.EvalCache(path)
    assert len(cache) == 0
    assert path.with_suffix(".json.corrupt").exists()


def test_cache_skips_retraining(tmp_path, monkeypatch):
    cache = BE.EvalCache(tmp_path / "evals.json")
    specs = SPECS[:2]
    first = BE.evaluate_population(CFG, specs, epochs=25, cache=cache)
    assert len(cache) == 2

    # a fully-cached population must never touch the finetune engine
    def boom(*a, **k):
        raise AssertionError("finetune ran on a fully-cached population")
    monkeypatch.setattr(BE, "_population_finetune", boom)
    again = BE.evaluate_population(CFG, specs, epochs=25, cache=cache)
    for a, b in zip(first, again):
        assert a.accuracy == b.accuracy and a.area_mm2 == b.area_mm2
