"""Pass framework over the netlist IR: rebuild walks + the PassManager.

A netlist is immutable-in-spirit (flat topo-ordered ids), so transforms are
expressed as a *rebuild*: walk the old nodes in order, keep an old->new id
map, and let a pass's rewriter intercept any node — returning a replacement
node id built with fresh builder calls (intervals and therefore widths are
re-derived by construction), or ``None`` to copy the node verbatim.
Downstream nodes see replacements through the map; orphaned subgraphs are
swept by a final dead-code rebuild. The classifier bookkeeping
(``layer_pre_ids`` / ``output_ids`` / ``argmax_id``) is remapped, so the
simulator and cost model work on transformed netlists unchanged.

Invariants every pass must preserve (DESIGN.md §4c):

* topological order (guaranteed by construction — rewriters only reference
  mapped, already-emitted nodes);
* one bias-add pre node per neuron, ``output_ids == layer_pre_ids[-1]``;
* role/layer/unit tags consistent with the microarchitecture the node
  implements (the cost model prices tags + topology, nothing else);
* any deviation from the exact reference semantics is declared, either
  structurally (TRUNC's intrinsic error) or via the node's local
  ``err_lo/err_hi`` annotation — `approx.analyze` must be able to bound
  the transformed circuit's worst-case logit error.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

from repro.circuit import ir

# rewriter(new_net, old_net, node, old_to_new_map) -> new id | None (= copy)
Rewriter = Callable[[ir.Netlist, ir.Netlist, ir.Node, Dict[int, int]],
                    Optional[int]]


def copy_node(new: ir.Netlist, n: ir.Node, m: Dict[int, int]) -> int:
    """Emit a verbatim copy of ``n`` into ``new`` with remapped args.
    Intervals are re-derived by the builders; tags, the product-root flag
    and local error annotations are preserved."""
    tags = dict(role=n.role, layer=n.layer, unit=n.unit)
    if n.op == ir.Op.CONST:
        nid = new.const(n.value, **tags)
    elif n.op == ir.Op.INPUT:
        nid = new.input(n.unit[0])
    elif n.op == ir.Op.SHL:
        nid = new.shl(m[n.args[0]], n.shift, **tags)
    elif n.op == ir.Op.TRUNC:
        nid = new.trunc(m[n.args[0]], n.shift, **tags)
    elif n.op == ir.Op.ADD:
        nid = new.add(m[n.args[0]], m[n.args[1]], **tags)
    elif n.op == ir.Op.SUB:
        nid = new.sub(m[n.args[0]], m[n.args[1]], **tags)
    elif n.op == ir.Op.NEG:
        nid = new.neg(m[n.args[0]], **tags)
    elif n.op == ir.Op.RELU:
        nid = new.relu(m[n.args[0]], **tags)
    elif n.op == ir.Op.ARGMAX:
        nid = new.argmax([m[a] for a in n.args])
    else:                                        # pragma: no cover
        raise ValueError(f"unknown op {n.op}")
    node = new.nodes[nid]
    node.product_root = node.product_root or n.product_root
    node.err_lo += n.err_lo
    node.err_hi += n.err_hi
    return nid


def live_set(net: ir.Netlist) -> set:
    """Nodes reachable from the classifier's observation points (argmax,
    logits, every layer's pre-activations) plus every ADC input lane (the
    physical interface exists whether or not a weight survives). Every
    activation node is likewise an observation point: a neuron whose
    outgoing weights are all pruned still prints its accumulator + ReLU
    (the PR 3 layer-interface convention the analytic ``act_fa`` prices),
    so DCE must not sweep it."""
    live = set()
    stack: List[int] = list(net.input_ids)
    if net.argmax_id is not None:
        stack.append(net.argmax_id)
    for layer in net.layer_pre_ids:
        stack.extend(layer)
    stack.extend(net.output_ids)
    stack.extend(n.id for n in net.nodes if n.op == ir.Op.RELU)
    while stack:
        i = stack.pop()
        if i in live:
            continue
        live.add(i)
        stack.extend(net.nodes[i].args)
    return live


def rebuild(net: ir.Netlist, rewriter: Optional[Rewriter] = None, *,
            dce: bool = False) -> ir.Netlist:
    """One rebuild walk. With ``dce`` dead nodes are skipped (INPUT nodes
    are always kept — they are the ADC interface). The result is validated."""
    new = ir.Netlist(in_bits=net.in_bits, w_bits=list(net.w_bits))
    keep = live_set(net) if dce else None
    m: Dict[int, int] = {}
    for n in net.nodes:
        if keep is not None and n.id not in keep:
            continue
        nid = rewriter(new, net, n, m) if rewriter is not None else None
        if nid is None:
            nid = copy_node(new, n, m)
        m[n.id] = nid
    new.layer_pre_ids = [[m[i] for i in layer] for layer in net.layer_pre_ids]
    new.output_ids = [m[i] for i in net.output_ids]
    new.validate()
    return new


class Pass:
    """One composable netlist transform. Subclasses implement ``run``
    (usually a single `rebuild` with a rewriter) and declare the
    metamorphic invariants the verified pipeline may hold them to."""

    name = "pass"
    # Declared metamorphic invariants, checked by PassManager's verify
    # mode after every application (in the sanctioned pipeline order —
    # `budget.build_passes` runs from an exact netlist):
    monotone_cost = False     # structural cost never increases
    monotone_bound = False    # proven error bounds only widen

    def run(self, net: ir.Netlist) -> ir.Netlist:
        raise NotImplementedError

    def __repr__(self):
        return f"<{type(self).__name__}>"


class PassManager:
    """Applies ordered passes, then one dead-code rebuild that compacts the
    netlist and re-validates it. With an empty pass list the result is
    semantically identical to the input: bit-exact simulation and exactly
    the same structural cost (the PR 3 invariants — tested).

    ``verify`` switches the instrumented pipeline on (None defers to the
    ambient ``REPRO_VERIFY`` flag — on under the test suite): the netlist
    verifier runs after *every* pass, and each pass's declared metamorphic
    invariants are differentially checked — cost never increases under the
    truncation passes, the interval-proven error bounds only widen along
    the pipeline, and the final DCE sweep moves neither."""

    def __init__(self, passes: Sequence[Pass] = (), *,
                 verify: Optional[bool] = None):
        self.passes = list(passes)
        self.verify = verify

    def run(self, net: ir.Netlist) -> ir.Netlist:
        from repro.obs import trace as TR
        from repro.verify.diagnostics import verify_enabled
        if not verify_enabled(self.verify):
            if TR.active():
                return self._run_traced(net)
            for p in self.passes:
                net = p.run(net)
            return rebuild(net, dce=True)
        return self._run_verified(net)

    def _run_traced(self, net: ir.Netlist) -> ir.Netlist:
        """The unverified pipeline under tracing: per-pass spans carrying
        the structural-cost and proven-bound deltas each pass bought.
        Deltas are measured on DCE'd snapshots (a rewrite orphans the
        subnets it replaces), which costs one extra rebuild per pass —
        priced only when ``REPRO_TRACE`` is on."""
        from repro.approx.analyze import logit_error_bound
        from repro.circuit.cost import structural_cost
        from repro.obs import metrics as MT
        from repro.obs import trace as TR
        snap = rebuild(net, dce=True)
        cost = structural_cost(snap).total_fa
        bound = logit_error_bound(snap)
        for p in self.passes:
            with TR.span("approx.pass", pass_name=p.name) as sp:
                net = p.run(net)
                snap = rebuild(net, dce=True)
                c2 = structural_cost(snap).total_fa
                b2 = logit_error_bound(snap)
                sp.set(cost_delta=round(c2 - cost, 6),
                       bound_delta=int(b2 - bound))
            MT.counter("approx.passes").inc()
            MT.histogram("approx.pass.cost_delta").observe(c2 - cost)
            cost, bound = c2, b2
        return snap

    def _run_verified(self, net: ir.Netlist) -> ir.Netlist:
        from repro.approx.analyze import (decision_error_bound,
                                          logit_error_bound)
        from repro.circuit.cost import structural_cost
        from repro.verify.diagnostics import (ERROR, Diagnostic,
                                              VerificationError)
        from repro.verify.netlist import check_netlist

        def fail(rule: str, msg: str):
            raise VerificationError([Diagnostic(ERROR, rule, msg)])

        def measure(n: ir.Netlist):
            """(DCE'd snapshot, its cost, its proven bounds). Differential
            checks must measure the *swept* netlist: a rewrite orphans the
            subnets it replaces, and those stay in the node list (inflating
            structural cost) until the final dead-code rebuild."""
            snap = rebuild(n, dce=True)
            return snap, structural_cost(snap).total_fa, (
                logit_error_bound(snap), decision_error_bound(snap))

        from repro.obs import metrics as MT
        from repro.obs import trace as TR

        # strict conventions are demanded of a pass only when its input
        # already met them (compiler outputs do; hand-built IR need not)
        strict = not check_netlist(net)
        snap, cost, bounds = measure(net)
        for p in self.passes:
            with TR.span("approx.pass", pass_name=p.name) as sp:
                net = p.run(net)
                raw = (logit_error_bound(net), decision_error_bound(net))
                snap, c2, b2 = measure(net)
                sp.set(cost_delta=round(c2 - cost, 6),
                       bound_delta=int(b2[0] - bounds[0]))
            MT.counter("approx.passes").inc()
            MT.histogram("approx.pass.cost_delta").observe(c2 - cost)
            check_netlist(snap, strict=strict, expect_dce=True)
            if raw != b2:
                fail("pass-bound",
                     f"{p.name}: dead-code sweep moved the proven bounds "
                     f"{raw} -> {b2} (DCE must be error-neutral)")
            if p.monotone_cost and c2 > cost + 1e-9:
                fail("pass-cost",
                     f"{p.name}: structural cost increased "
                     f"{cost:.3f} -> {c2:.3f} under a truncation pass")
            if p.monotone_bound and (b2[0] < bounds[0]
                                     or b2[1] < bounds[1]):
                fail("pass-bound",
                     f"{p.name}: proven error bounds narrowed "
                     f"{bounds} -> {b2} — a rewrite lost declared error")
            cost, bounds = c2, b2
        # the last snapshot IS the pipeline result (same final rebuild the
        # unverified path performs)
        return snap
