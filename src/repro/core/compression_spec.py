"""Compression specifications — the genome of the hardware-aware search.

A :class:`LayerMin` is the per-layer minimization choice (quantization bits,
pruning sparsity, cluster count); a :class:`ModelMin` is one choice per
compressible layer. The same spec drives:

* the printed-MLP path (`core.minimize`): QAT retraining + bespoke compile +
  printed-area objective (the paper, faithfully);
* the LM path (`core.lm_compress` / examples): weight-pytree transforms +
  TPU roofline objective (`core.tpu_cost`) — the beyond-paper integration.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import clustering as C
from repro.core import pruning as P
from repro.core import quantization as Q


@dataclasses.dataclass(frozen=True)
class LayerMin:
    bits: Optional[int] = None         # None = full precision
    sparsity: float = 0.0
    clusters: Optional[int] = None     # None = no clustering
    # circuit-approximation genes (repro.approx; 0 = exact):
    csd_drop: int = 0                  # CSD digits dropped per multiplier
    lsb: int = 0                       # low bits truncated off accum trees

    def validate(self):
        assert self.bits is None or 2 <= self.bits <= 8, self.bits
        assert 0.0 <= self.sparsity <= 0.9, self.sparsity
        assert self.clusters is None or 2 <= self.clusters <= 64
        assert 0 <= self.csd_drop <= 8, self.csd_drop
        assert 0 <= self.lsb <= 16, self.lsb


@dataclasses.dataclass(frozen=True)
class ModelMin:
    layers: Tuple[LayerMin, ...]
    input_bits: int = 8
    argmax_lsb: int = 0                # argmax comparator-input truncation

    def validate(self):
        for l in self.layers:
            l.validate()
        assert 0 <= self.argmax_lsb <= 16, self.argmax_lsb

    @property
    def has_approx(self) -> bool:
        """Any circuit-approximation gene active — such specs must be
        priced structurally and scored on the simulated netlist (the
        analytic model and the float emulation describe the exact
        circuit, which is no longer what gets printed)."""
        return bool(self.argmax_lsb
                    or any(l.csd_drop or l.lsb for l in self.layers))

    def to_json(self) -> str:
        # approximation genes are serialized only when active, so every
        # exact spec keeps its historical JSON byte-for-byte (EvalCache
        # keys embed this string — old caches stay valid)
        layers = []
        for l in self.layers:
            d = {"bits": l.bits, "sparsity": l.sparsity,
                 "clusters": l.clusters}
            if l.csd_drop:
                d["csd_drop"] = l.csd_drop
            if l.lsb:
                d["lsb"] = l.lsb
            layers.append(d)
        out = {"input_bits": self.input_bits, "layers": layers}
        if self.argmax_lsb:
            out["argmax_lsb"] = self.argmax_lsb
        return json.dumps(out)

    @staticmethod
    def from_json(s: str) -> "ModelMin":
        d = json.loads(s)
        return ModelMin(tuple(LayerMin(**l) for l in d["layers"]),
                        d["input_bits"], d.get("argmax_lsb", 0))

    @staticmethod
    def uniform(n_layers: int, *, bits=None, sparsity=0.0, clusters=None,
                csd_drop: int = 0, lsb: int = 0, input_bits: int = 8,
                argmax_lsb: int = 0) -> "ModelMin":
        return ModelMin(tuple(LayerMin(bits, sparsity, clusters, csd_drop,
                                       lsb)
                              for _ in range(n_layers)), input_bits,
                        argmax_lsb)


def qat_weight(w: jnp.ndarray, spec: LayerMin, mask=None) -> jnp.ndarray:
    """QAT forward transform (prune -> cluster -> quantize), all STE.
    Order matters: the bespoke circuit hardwires quantized shared products of
    surviving connections, so quantization is the outermost grid snap."""
    if mask is not None:
        w = P.apply_mask(w, mask)
    if spec.clusters is not None and w.ndim == 2:
        w = C.cluster_ste(w, spec.clusters, per_input=True)
    if spec.bits is not None:
        w = Q.fake_quant(w, Q.QuantConfig(bits=spec.bits))
    return w
