"""int8 gradient compression: quantization error bounds + error feedback."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import grad_compression as GC


def test_leaf_roundtrip_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    q, s = GC.quantize_leaf(g)
    err = np.abs(np.asarray(GC.dequantize_leaf(q, s)) - np.asarray(g))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_accumulates_unbiased():
    """Sum over steps of compressed grads ~= sum of true grads (EF property)."""
    key = jax.random.PRNGKey(1)
    err = jnp.zeros((32,))
    total_true = jnp.zeros((32,))
    total_sent = jnp.zeros((32,))
    for i in range(30):
        key, k = jax.random.split(key)
        g = jax.random.normal(k, (32,)) * 0.1
        total_true = total_true + g
        carried = g + err
        q, s = GC.quantize_leaf(carried)
        sent = GC.dequantize_leaf(q, s)
        err = carried - sent
        total_sent = total_sent + sent
    # residual bounded by one quantization step, not growing with steps
    resid = np.abs(np.asarray(total_true - total_sent))
    assert resid.max() < 0.05


def test_compressed_allreduce_single_device_mesh():
    mesh = jax.make_mesh((1,), ("pod",))
    allreduce = GC.make_compressed_allreduce(mesh, "pod")
    grads = {"w": jnp.linspace(-1, 1, 16), "b": jnp.ones(4)}
    err = GC.init_error_state(grads)
    mean, new_err = allreduce(grads, err)
    np.testing.assert_allclose(np.asarray(mean["w"]),
                               np.asarray(grads["w"]), atol=0.02)
    # error state holds the quantization residual
    np.testing.assert_allclose(
        np.asarray(new_err["w"]),
        np.asarray(grads["w"] - mean["w"]), atol=1e-6)
