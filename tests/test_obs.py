"""Observability layer (`repro.obs`): tracer contracts, torn-tail salvage,
bounded ring logs, metrics snapshot/restore through checkpoint/resume
(counter bit-identity), and the run-report renderer against a committed
golden trace."""
import contextlib
import json
from pathlib import Path

import pytest

from repro.core.ga import GAConfig
from repro.obs import metrics as MT
from repro.obs import report
from repro.obs import trace as TR
from repro.obs.ring import RingLog
from repro.search import (IslandConfig, PreemptedError, SearchConfig,
                          SearchRuntime)
from repro.search.faults import FaultHarness, FaultPlan

DATA = Path(__file__).resolve().parent / "data"


@contextlib.contextmanager
def _tracing_off():
    """Detach any ambient tracer (CI runs this file under REPRO_TRACE=1 to
    exercise telemetry on the fault paths; the off-path contracts below
    need tracing genuinely off)."""
    prev, TR._tracer = TR._tracer, None
    try:
        yield
    finally:
        TR._tracer = prev


# ---------------------------------------------------------------------------
# tracer: off-path contract, span nesting, exception safety
# ---------------------------------------------------------------------------


def test_off_path_is_inert(tmp_path):
    """With no tracer installed: null span, no first-call tracking, and no
    Tracer (the only obs path to file IO) is ever constructed."""
    constructed = []
    init = TR.Tracer.__init__

    def counting(self, path):
        constructed.append(str(path))
        init(self, path)

    TR.Tracer.__init__ = counting
    try:
        with _tracing_off():
            assert not TR.active()
            with TR.span("anything", a=1) as sp:
                sp.set(b=2)
            TR.event("anything", x=1)
            assert TR.first_call("k") is False
            assert TR.first_call("k") is False
            assert TR.tracing_to() is None
    finally:
        TR.Tracer.__init__ = init
    assert constructed == []


def test_span_nesting_depth_and_attrs(tmp_path):
    p = tmp_path / "t.jsonl"
    with TR.capture(p):
        with TR.span("outer", a=1) as sp:
            with TR.span("inner"):
                TR.event("tick", n=3)
            sp.set(late=True)
    recs, damaged = TR.read_trace(p)
    assert damaged == 0
    assert [r["kind"] for r in recs] == ["meta", "event", "span", "span"]
    ev, inner, outer = recs[1], recs[2], recs[3]
    assert ev["name"] == "tick" and ev["attrs"] == {"n": 3}
    # spans emit on exit: inner closes first, depths record the nesting
    assert inner["name"] == "inner" and inner["depth"] == 1
    assert outer["name"] == "outer" and outer["depth"] == 0
    assert outer["attrs"] == {"a": 1, "late": True}
    assert outer["dur"] >= inner["dur"] >= 0


def test_span_exception_recorded_and_propagated(tmp_path):
    p = tmp_path / "t.jsonl"
    with TR.capture(p):
        with pytest.raises(ValueError):
            with TR.span("boom"):
                raise ValueError("x")
    recs, _ = TR.read_trace(p)
    boom = [r for r in recs if r.get("name") == "boom"]
    assert len(boom) == 1 and boom[0]["error"] == "ValueError"


def test_first_call_once_per_key_per_tracer(tmp_path):
    with TR.capture(tmp_path / "a.jsonl"):
        assert TR.first_call(("k", 1)) is True
        assert TR.first_call(("k", 1)) is False
        assert TR.first_call(("k", 2)) is True
    with TR.capture(tmp_path / "b.jsonl"):
        # a fresh tracer is a fresh process-lifetime: compile again
        assert TR.first_call(("k", 1)) is True


def test_capture_restores_previous_tracer(tmp_path):
    outer, inner = tmp_path / "outer.jsonl", tmp_path / "inner.jsonl"
    with _tracing_off():
        with TR.capture(outer):
            with TR.capture(inner):
                TR.event("in")
            assert TR.tracing_to() == outer
            TR.event("out")
        assert not TR.active()
    assert [r["name"] for r in TR.read_trace(inner)[0]
            if r["kind"] == "event"] == ["in"]
    assert [r["name"] for r in TR.read_trace(outer)[0]
            if r["kind"] == "event"] == ["out"]


def test_read_trace_salvages_torn_tail(tmp_path):
    p = tmp_path / "t.jsonl"
    with TR.capture(p):
        for i in range(10):
            TR.event("e", i=i)
    whole = p.read_bytes()
    torn = tmp_path / "torn.jsonl"
    torn.write_bytes(whole[:-17])           # tear the last record mid-line
    recs, damaged = TR.read_trace(torn)
    assert damaged == 1
    events = [r for r in recs if r["kind"] == "event"]
    assert [e["attrs"]["i"] for e in events] == list(range(9))


def test_default_path_from_env(monkeypatch):
    monkeypatch.setenv(TR.ENV_FLAG, "1")
    assert TR.default_path() == Path("repro_trace.jsonl")
    monkeypatch.setenv(TR.ENV_FLAG, "/tmp/run7.jsonl")
    assert TR.default_path() == Path("/tmp/run7.jsonl")


# ---------------------------------------------------------------------------
# ring log
# ---------------------------------------------------------------------------


def test_ringlog_bounds_and_counts():
    r = RingLog(cap=3)
    for i in range(7):
        r.append(i)
    assert list(r) == [4, 5, 6]
    assert len(r) == 3 and r.total == 7 and r.dropped == 4
    assert r[0] == 4 and r[-1] == 6 and r[1:] == [5, 6]


def test_ringlog_spills_every_append():
    spilled = []
    r = RingLog(cap=2, spill=spilled.append)
    r.extend([{"event": "a"}, {"event": "b"}, {"event": "c"}])
    assert list(r) == [{"event": "b"}, {"event": "c"}]   # ring keeps a tail
    assert spilled == [{"event": "a"}, {"event": "b"}, {"event": "c"}]


def test_ringlog_full_slice_restore_bypasses_spill():
    spilled = []
    r = RingLog(cap=4, spill=spilled.append)
    r.extend([1, 2, 3])
    r[:] = [8, 9]                          # checkpoint-restore idiom
    assert list(r) == [8, 9] and r.total == 2 and r.dropped == 0
    assert spilled == [1, 2, 3]            # restore did not re-spill
    with pytest.raises(TypeError):
        r[0] = 5                           # only full-slice assignment


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_metrics_snapshot_restore_roundtrip():
    reg = MT.MetricsRegistry()
    reg.counter("c").inc(3)
    reg.gauge("g").set(2.5)
    h = reg.histogram("h")
    h.observe(1.0)
    h.observe(3.0)
    snap = reg.snapshot()
    assert snap["counters"] == {"c": 3}
    assert snap["histograms"]["h"] == {"count": 2, "sum": 4.0,
                                       "min": 1.0, "max": 3.0}
    reg2 = MT.MetricsRegistry()
    reg2.restore(snap)
    assert reg2.snapshot() == snap
    assert json.dumps(snap, sort_keys=True)  # checkpoint-serializable


def test_metrics_snapshot_key_order_deterministic():
    reg = MT.MetricsRegistry()
    reg.counter("z").inc()
    reg.counter("a").inc()
    assert list(reg.snapshot()["counters"]) == ["a", "z"]


# ---------------------------------------------------------------------------
# counter bit-identity across preempt + resume
# ---------------------------------------------------------------------------


def _synthetic(spec):
    bits = sum(l.bits for l in spec.layers)
    sp = sum(l.sparsity for l in spec.layers)
    return (bits / 16.0, sp)


def _cfg():
    return SearchConfig(
        n_layers=2, rounds=4,
        ga=GAConfig(population=6, seed=3),
        islands=IslandConfig(n_islands=2, migration_every=2, migrants=1))


def test_counters_bit_identical_across_preempt_resume(tmp_path):
    """The metric-counter contract: counters hold exact integer counts of
    deterministic search quantities, so a preempted-and-resumed run ends
    with exactly the uninterrupted run's counters (gauges/histograms carry
    wall-clock and are exempt)."""
    MT.REGISTRY.reset()
    SearchRuntime(_cfg(), evaluate=_synthetic).run()
    uninterrupted = MT.snapshot()["counters"]

    MT.REGISTRY.reset()
    rt = SearchRuntime(_cfg(), evaluate=_synthetic, ckpt_root=tmp_path,
                       harness=FaultHarness(FaultPlan(preempt_at=1)))
    with pytest.raises(PreemptedError):
        rt.run()
    MT.REGISTRY.reset()                    # simulate the fresh process
    SearchRuntime.resume(_cfg(), tmp_path, evaluate=_synthetic).run()
    resumed = MT.snapshot()["counters"]

    assert uninterrupted  # the run did count things
    assert resumed == uninterrupted


def test_checkpoint_meta_carries_ring_totals_and_metrics(tmp_path):
    MT.REGISTRY.reset()
    rt = SearchRuntime(_cfg(), evaluate=_synthetic, ckpt_root=tmp_path,
                       harness=FaultHarness(FaultPlan(preempt_at=1)))
    with pytest.raises(PreemptedError):
        rt.run()
    MT.REGISTRY.reset()
    rt2 = SearchRuntime.resume(_cfg(), tmp_path, evaluate=_synthetic)
    # restore() reinstated the registry from checkpoint meta, not zero
    assert MT.snapshot()["counters"].get("fleet.rounds") == 2
    assert isinstance(rt2.fleet.events, (RingLog, list))


# ---------------------------------------------------------------------------
# report: golden render of a committed 2-island trace
# ---------------------------------------------------------------------------


def _fixture_records():
    recs, damaged = TR.read_trace(DATA / "obs_trace_2island.jsonl")
    assert damaged == 0
    return recs


def test_report_golden():
    """Rendering is deterministic for a given trace file: the committed
    2-island faulted run (straggler ejection, migration, island kill)
    renders byte-identically to its golden report."""
    txt = report.render(_fixture_records(), 0, "obs_trace_2island.jsonl")
    golden = (DATA / "obs_report_2island.txt").read_text()
    assert txt == golden


def test_report_reconstructs_run_structure():
    recs = _fixture_records()
    tl = report.island_timelines(recs)
    assert set(tl) == {0, 1}
    assert len(tl[0]) == 4                        # island 0 ran every round
    assert any(g["error"] == "IslandKilled" for g in tl[1])
    led = report.ledger(recs)
    assert [e["name"] for e in led] == ["fleet.straggler_ejected",
                                       "fleet.migration", "fleet.killed"]
    hv = report.hypervolume_progress(recs)
    assert hv and all(h["hv_proxy"] >= 0 for h in hv)
    # within one island the hv proxy never decreases on this fixture
    by_island = {}
    for h in hv:
        prev = by_island.get(h["island"])
        assert prev is None or h["hv_proxy"] >= prev - 1e-12
        by_island[h["island"]] = h["hv_proxy"]
    rounds = [c for c in report.cache_curve(recs) if "round" in c]
    assert [c["round"] for c in rounds] == [0, 1, 2, 3]
    assert all(0.0 <= c["hit_rate"] <= 1.0 for c in rounds)


def test_report_cli_and_csv(tmp_path, capsys):
    prefix = tmp_path / "run"
    rc = report.main([str(DATA / "obs_trace_2island.jsonl"),
                      "--csv", str(prefix)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "wall-clock by span" in out and "fault/quarantine ledger" in out
    for section in ("spans", "generations", "cache", "ledger"):
        f = Path(f"{prefix}.{section}.csv")
        assert f.exists() and f.read_text().strip()


def test_hv_2d_exact():
    # staircase of two non-dominated points against ref (3,3):
    # (1,2) contributes 2x1, (2,1) adds 1x1 -> 3
    assert report._hv_2d([(1, 2), (2, 1)], (3, 3)) == pytest.approx(3.0)
    # a dominated point adds nothing
    assert report._hv_2d([(1, 2), (2, 1), (2.5, 2.5)],
                         (3, 3)) == pytest.approx(3.0)
    # points outside the ref are ignored
    assert report._hv_2d([(4, 0.5)], (3, 3)) == 0.0


# ---------------------------------------------------------------------------
# traced searches stay bit-identical to untraced ones
# ---------------------------------------------------------------------------


def test_tracing_does_not_perturb_search(tmp_path):
    with _tracing_off():
        base = SearchRuntime(_cfg(), evaluate=_synthetic).run()
    with TR.capture(tmp_path / "t.jsonl"):
        traced = SearchRuntime(_cfg(), evaluate=_synthetic).run()
    assert [s.to_json() for s in traced.front_specs] == \
        [s.to_json() for s in base.front_specs]
