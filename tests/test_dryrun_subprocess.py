"""End-to-end dry-run test in a subprocess (so the forced 512-device XLA flag
never leaks into this test process)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen3-0.6b", "--shape", "decode_32k",
         "--mesh", "both", "--out", str(tmp_path), "--force",
         "--skip-reduced"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    for mesh in ("single", "multi"):
        rec = json.loads(
            (tmp_path / f"qwen3-0.6b__decode_32k__{mesh}.json").read_text())
        assert rec["status"] == "ok"
        assert rec["chips"] == (256 if mesh == "single" else 512)
        assert rec["memory"]["argument_bytes"] > 0
        assert rec["compile_s"] > 0


def test_existing_artifacts_cover_all_cells():
    """The committed sweep must cover every (arch x shape x mesh) cell with
    ok or a documented skip."""
    art = ROOT / "artifacts" / "dryrun"
    if not art.exists() or len(list(art.glob("*.json"))) < 80:
        pytest.skip("full sweep not complete yet")
    from repro.configs import ARCHS, SHAPES, shape_applicable
    for arch, cfg in ARCHS.items():
        for shape_name, shape in SHAPES.items():
            for mesh in ("single", "multi"):
                f = art / f"{arch}__{shape_name}__{mesh}.json"
                assert f.exists(), f.name
                rec = json.loads(f.read_text())
                ok, why = shape_applicable(cfg, shape)
                if ok:
                    assert rec["status"] == "ok", (f.name, rec.get("error"))
                else:
                    assert rec["status"] == "skipped"
                    assert rec["reason"]
