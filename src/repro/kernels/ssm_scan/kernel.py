"""Selective-scan (Mamba-1) Pallas kernel: the time loop runs INSIDE the
kernel with the recurrent state resident in VMEM scratch.

This is the TPU-native analogue of the CUDA selective_scan kernel (DESIGN.md
§3): the HBM-visible traffic is exactly the inputs/outputs (u, dt, B, C -> y);
the (bd, N) state h never leaves VMEM. The pure-JAX `lax.scan` path
(repro.nn.ssm) round-trips the carry per step on non-fused backends — this
kernel is what the falcon-mamba roofline projects onto for the TPU target.

Grid: (B, d/bd, T/bt). The T axis is sequential ("arbitrary" semantics); the
carry persists in scratch across the T-grid steps of the same (b, d-block).
Within a block, bt time steps unroll (bt small: the recurrence is serial).

  h_t = exp(dt_t * A) * h_{t-1} + (dt_t * u_t) B_t ;  y_t = h_t . C_t + D u_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams


def _ssm_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_ref, *,
                bt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...]                                   # (bd, N) fp32
    d = d_ref[...]                                   # (1, bd)
    h = h_ref[...]                                   # (bd, N)
    for t in range(bt):                              # serial recurrence
        u_t = u_ref[0, t, :].astype(jnp.float32)     # (bd,)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)   # (bd,)
        b_t = b_ref[0, t, :].astype(jnp.float32)     # (N,)
        c_t = c_ref[0, t, :].astype(jnp.float32)     # (N,)
        da = jnp.exp(dt_t[:, None] * a)              # (bd, N)
        h = da * h + (dt_t * u_t)[:, None] * b_t[None, :]
        y = jnp.sum(h * c_t[None, :], axis=1) + d[0] * u_t
        y_ref[0, t, :] = y.astype(y_ref.dtype)
    h_ref[...] = h


def ssm_scan_pallas(u, dt, B_, C_, A, D, *, block_d: int = 512,
                    block_t: int = 8, interpret: bool = False):
    """u/dt: (B, T, d); B_/C_: (B, T, N); A: (d, N) (negative); D: (d,).
    Returns y (B, T, d). d % block_d == 0, T % block_t == 0 (ops.py pads T)."""
    Bsz, T, d = u.shape
    N = A.shape[1]
    assert d % block_d == 0 and T % block_t == 0
    grid = (Bsz, d // block_d, T // block_t)

    return pl.pallas_call(
        functools.partial(_ssm_kernel, bt=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda b, i, t: (b, t, i)),
            pl.BlockSpec((1, block_t, block_d), lambda b, i, t: (b, t, i)),
            pl.BlockSpec((1, block_t, N), lambda b, i, t: (b, t, 0)),
            pl.BlockSpec((1, block_t, N), lambda b, i, t: (b, t, 0)),
            pl.BlockSpec((block_d, N), lambda b, i, t: (i, 0)),
            pl.BlockSpec((1, block_d), lambda b, i, t: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_d),
                               lambda b, i, t: (b, t, i)),
        out_shape=jax.ShapeDtypeStruct((Bsz, T, d), u.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(u, dt, B_, C_, A.astype(jnp.float32),
      D.astype(jnp.float32).reshape(1, d))
