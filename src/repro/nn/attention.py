"""Attention mixers: GQA/MQA (global, sliding-window, cross) and MLA.

Implementation notes
--------------------
* GQA grouping is explicit: q heads are reshaped to (KV, G) so the kv tensors
  never need repeating (saves HBM bytes and keeps the einsum MXU-shaped).
* Long-sequence prefill uses an online-softmax scan over KV chunks
  ("flash-in-XLA"): peak memory O(T * chunk) instead of O(T^2). The Pallas
  `flash_attention` kernel (repro.kernels) is the TPU-native version; the
  chunked jnp path below is the portable default used by the dry-run.
* Sliding-window ("local") attention is *banded*: q blocks of size W attend to
  their own and the previous kv block only -> O(T * 2W) FLOPs, which is what
  makes recurrentgemma/gemma2 local layers cheap at long context.
* MLA (DeepSeek-V2) caches the compressed c_kv (kv_lora + rope dims) and uses
  the *absorbed* formulation at decode so per-token FLOPs scale with
  kv_lora_rank, not heads * head_dim.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MLAConfig
from repro.nn import layers as L

NEG_INF = -2.0e38

# Measurement hook (repro.launch.dryrun): XLA cost_analysis counts while-loop
# bodies once, so the chunked-softmax scan under-reports score bytes/FLOPs by
# ~S/chunk. The dry-run's unrolled cost lowers set CHUNK_OVERRIDE to force
# the single-einsum path, whose *total* traffic equals the chunked path's.
CHUNK_OVERRIDE = None


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ArchConfig, dtype, *, bias: bool = False,
              cross: bool = False):
    d, H, KV = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wq": L.dense_init(ks[0], d, H * hd, dtype, out_shape=(H, hd), bias=bias),
        "wk": L.dense_init(ks[1], d, KV * hd, dtype, out_shape=(KV, hd), bias=bias),
        "wv": L.dense_init(ks[2], d, KV * hd, dtype, out_shape=(KV, hd), bias=bias),
        "wo": L.dense_in3_init(ks[3], H, hd, d, dtype, bias=bias),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.norm_init(hd, "rmsnorm")
        p["k_norm"] = L.norm_init(hd, "rmsnorm")
    if cross:
        p["c_wq"] = L.dense_init(ks[4], d, H * hd, dtype, out_shape=(H, hd), bias=bias)
        p["c_wk"] = L.dense_init(ks[5], d, KV * hd, dtype, out_shape=(KV, hd), bias=bias)
        p["c_wv"] = L.dense_init(ks[6], d, KV * hd, dtype, out_shape=(KV, hd), bias=bias)
        p["c_wo"] = L.dense_in3_init(ks[7], H, hd, d, dtype, bias=bias)
    return p


# ---------------------------------------------------------------------------
# core attend
# ---------------------------------------------------------------------------


def _gqa_scores(q, k, scale, dtype=jnp.float32):
    """q: (B,T,KV,G,hd)  k: (B,S,KV,hd) -> (B,KV,G,T,S)"""
    return (jnp.einsum("btkgh,bskh->bkgts", q.astype(dtype),
                       k.astype(dtype),
                       preferred_element_type=dtype) * dtype(scale))


def _mask_bias(q_pos, k_pos, *, causal: bool, window: int, kv_len=None):
    """(T,S) additive bias in fp32. q_pos/k_pos: int32 vectors."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    if kv_len is not None:
        ok &= k_pos[None, :] < kv_len
    return jnp.where(ok, 0.0, NEG_INF)


def attend(q, k, v, *, causal: bool, window: int = 0, softcap: float = 0.0,
           q_offset=0, kv_len=None, chunk: int = 2048, k_positions=None,
           lowp: bool = False):
    """General attention. q: (B,T,H,hd); k/v: (B,S,KV,hd). Returns (B,T,H,hd).

    q_offset:    absolute position of q[0] (decode: cache length). May be traced.
    kv_len:      valid kv prefix length (decode with preallocated cache).
    k_positions: explicit absolute position per kv slot (ring buffers). Only
                 supported on the single-chunk path.
    """
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    vd = v.shape[-1]                    # may differ from hd (MLA)
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    sdt = jnp.bfloat16 if lowp else jnp.float32
    if CHUNK_OVERRIDE is not None:
        chunk = CHUNK_OVERRIDE
    qg = q.reshape(B, T, KV, G, hd)
    q_pos = jnp.arange(T, dtype=jnp.int32) + q_offset

    if S <= chunk or T == 1 or k_positions is not None:
        k_pos = (jnp.arange(S, dtype=jnp.int32)
                 if k_positions is None else k_positions)
        s = _gqa_scores(qg, k, scale, sdt)
        s = L.softcap(s, softcap)
        if k_positions is None:
            bias = _mask_bias(q_pos, k_pos, causal=causal, window=window,
                              kv_len=kv_len)
        else:
            ok = (k_pos[None, :] >= 0) & (k_pos[None, :] <= q_pos[:, None])
            if window:
                ok &= k_pos[None, :] > q_pos[:, None] - window
            bias = jnp.where(ok, 0.0, NEG_INF)
        s = s + bias.astype(sdt)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgts,bskh->btkgh", p, v.astype(sdt),
                       preferred_element_type=jnp.float32)
        return o.reshape(B, T, H, vd).astype(q.dtype)

    # --- online-softmax scan over KV chunks (flash-in-XLA) -----------------
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KV, vd).transpose(1, 0, 2, 3, 4)
    eff_len = kv_len if kv_len is not None else S

    def step(carry, xs):
        m, l, acc = carry
        ci, kb, vb = xs
        k_pos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        s = _gqa_scores(qg, kb, scale, sdt)
        s = L.softcap(s, softcap)
        s = s + _mask_bias(q_pos, k_pos, causal=causal, window=window,
                           kv_len=eff_len).astype(sdt)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1).astype(jnp.float32))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s.astype(jnp.float32) - m_new[..., None]).astype(sdt)
        l_new = l * corr + jnp.sum(p, axis=-1).astype(jnp.float32)
        o = jnp.einsum("bkgts,bskh->bkgth", p, vb.astype(sdt),
                       preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + o
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, T), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, T), jnp.float32)
    a0 = jnp.zeros((B, KV, G, T, vd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.arange(n_chunks, dtype=jnp.int32), kc, vc))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(B, T, H, vd).astype(q.dtype)


def attend_local_banded(q, k, v, *, window: int, softcap: float = 0.0,
                        lowp: bool = False):
    """Exact sliding-window causal attention in O(T * 2W).

    q/k/v: (B,T,H|KV,hd), T % window may be ragged (padded internally).
    Each q block of size W attends to kv blocks [i-1, i] with an in-band mask.
    """
    B, T, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    W = window
    nb = -(-T // W)
    pad = nb * W - T
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    qb = qp.reshape(B, nb, W, KV, G, hd)
    kb = kp.reshape(B, nb, W, KV, hd)
    vb = vp.reshape(B, nb, W, KV, hd)
    # kv for block i = concat(block i-1, block i)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)          # (B,nb,2W,KV,hd)
    v2 = jnp.concatenate([v_prev, vb], axis=2)
    scale = 1.0 / math.sqrt(hd)
    sdt = jnp.bfloat16 if lowp else jnp.float32
    s = jnp.einsum("bnqkgh,bnskh->bnkgqs", qb.astype(sdt),
                   k2.astype(sdt), preferred_element_type=sdt) * sdt(scale)
    s = L.softcap(s, softcap)
    q_pos = jnp.arange(W)[:, None]                       # within-block q idx
    k_pos = jnp.arange(2 * W)[None, :] - W               # relative to block start
    ok = (k_pos <= q_pos) & (k_pos > q_pos - W)
    # first block must not see the zero-padded "previous" block
    first = jnp.arange(nb)[:, None, None] == 0
    ok = ok[None, :, :] & ~(first & (k_pos[None] < 0))
    s = s + jnp.where(ok, 0.0, NEG_INF).astype(sdt)[:, None, None]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnkgqs,bnskh->bnqkgh", p, v2.astype(sdt),
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, nb * W, H, hd)[:, :T]
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# full mixer: project -> rope -> attend -> out
# ---------------------------------------------------------------------------


def attn_apply(p, x, cfg: ArchConfig, *, mixer: str, positions=None,
               cache=None, kv_len=None, enc_out=None, enc_cache=None):
    """Self-attention (+ optional cross). Returns (out, new_cache).

    cache: None (train/prefill no-cache) or dict(k=(B,S,KV,hd), v=...) with
    kv_len giving the number of valid entries (decode).
    """
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    q = L.dense_apply(p["wq"], x)           # (B,T,H,hd)
    k = L.dense_apply(p["wk"], x)           # (B,T,KV,hd)
    v = L.dense_apply(p["wv"], x)
    if cfg.qk_norm:
        q = L.norm_apply(p["q_norm"], q, "rmsnorm", unit_offset=cfg.norm_unit_offset)
        k = L.norm_apply(p["k_norm"], k, "rmsnorm", unit_offset=cfg.norm_unit_offset)
    if positions is None:
        positions = jnp.arange(T, dtype=jnp.int32)[None, :] + (
            0 if kv_len is None else kv_len)
    if cfg.use_rope:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        S_buf = cache["k"].shape[1]
        if mixer == "local" and S_buf < 10**9 and S_buf == cfg.window_size:
            # ring buffer: slot j holds absolute position
            # a_j = kv_len - ((kv_len - j) mod S_buf)  (T==1 decode only)
            slot = jnp.mod(kv_len, S_buf)
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
            new_cache = {"k": ck, "v": cv}
            j = jnp.arange(S_buf, dtype=jnp.int32)
            k_pos = kv_len - jnp.mod(kv_len - j, S_buf)
            o = attend(q, ck, cv, causal=True, window=cfg.window_size,
                       softcap=cfg.attn_softcap, q_offset=kv_len,
                       k_positions=k_pos, lowp=cfg.attn_lowp_probs)
        else:
            # write this step's k/v at kv_len, attend over the whole buffer
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), kv_len, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), kv_len, axis=1)
            new_cache = {"k": ck, "v": cv}
            window = cfg.window_size if mixer == "local" else 0
            o = attend(q, ck, cv, causal=True, window=window,
                       softcap=cfg.attn_softcap, q_offset=kv_len,
                       kv_len=kv_len + T, lowp=cfg.attn_lowp_probs)
    elif mixer == "local" and T > cfg.window_size:
        o = attend_local_banded(q, k, v, window=cfg.window_size,
                                softcap=cfg.attn_softcap,
                                lowp=cfg.attn_lowp_probs)
    else:
        window = cfg.window_size if mixer == "local" else 0
        o = attend(q, k, v, causal=True, window=window,
                   softcap=cfg.attn_softcap, lowp=cfg.attn_lowp_probs)
    out = L.dense_in3_apply(p["wo"], o)

    if mixer == "cross":
        cq = L.dense_apply(p["c_wq"], x)
        if enc_cache is not None:
            ek, ev = enc_cache["k"], enc_cache["v"]
        else:
            ek = L.dense_apply(p["c_wk"], enc_out)
            ev = L.dense_apply(p["c_wv"], enc_out)
        co = attend(cq, ek, ev, causal=False)
        out = out + L.dense_in3_apply(p["c_wo"], co)
    return out, new_cache


def encoder_attn_apply(p, x, cfg: ArchConfig):
    """Bidirectional self-attention (whisper encoder)."""
    q = L.dense_apply(p["wq"], x)
    k = L.dense_apply(p["wk"], x)
    v = L.dense_apply(p["wv"], x)
    o = attend(q, k, v, causal=False)
    return L.dense_in3_apply(p["wo"], o)


def make_attn_cache(cfg: ArchConfig, batch: int, max_len: int, dtype, *,
                    mixer: str = "attn"):
    """Cache for one attention layer. Local (sliding-window) layers use a
    ring buffer of exactly `window` slots — O(window) state is what makes
    hybrid archs decodable at 500k context."""
    hd = cfg.resolved_head_dim
    S = max_len if mixer != "local" else min(max_len, cfg.window_size)
    return {
        "k": jnp.zeros((batch, S, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, S, cfg.num_kv_heads, hd), dtype),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ArchConfig, dtype):
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 8)
    qk = m.qk_nope_head_dim
    return {
        "w_dq": L.dense_init(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": L.norm_init(m.q_lora_rank, "rmsnorm"),
        "w_uq": L.dense_init(ks[1], m.q_lora_rank, H * (qk + m.qk_rope_head_dim),
                             dtype, out_shape=(H, qk + m.qk_rope_head_dim)),
        "w_dkv": L.dense_init(ks[2], d, m.kv_lora_rank, dtype),
        "kv_norm": L.norm_init(m.kv_lora_rank, "rmsnorm"),
        "w_uk": L.dense_init(ks[3], m.kv_lora_rank, H * qk, dtype,
                             out_shape=(H, qk)),
        "w_uv": L.dense_init(ks[4], m.kv_lora_rank, H * m.v_head_dim, dtype,
                             out_shape=(H, m.v_head_dim)),
        "w_kr": L.dense_init(ks[5], d, m.qk_rope_head_dim, dtype),
        "wo": L.dense_in3_init(ks[6], H, m.v_head_dim, d, dtype),
    }


def mla_apply(p, x, cfg: ArchConfig, *, cache=None, kv_len=None):
    """Returns (out, new_cache). Cache = compressed {c_kv, k_rope}."""
    m: MLAConfig = cfg.mla
    B, T, _ = x.shape
    H = cfg.num_heads
    qk, qr = m.qk_nope_head_dim, m.qk_rope_head_dim
    scale = 1.0 / math.sqrt(qk + qr)

    cq = L.norm_apply(p["q_norm"], L.dense_apply(p["w_dq"], x), "rmsnorm")
    q = L.dense_apply(p["w_uq"], cq)                     # (B,T,H,qk+qr)
    q_nope, q_rope = q[..., :qk], q[..., qk:]
    c_kv = L.norm_apply(p["kv_norm"], L.dense_apply(p["w_dkv"], x), "rmsnorm")
    k_rope = L.dense_apply(p["w_kr"], x)[:, :, None, :]  # (B,T,1,qr)
    offset = 0 if kv_len is None else kv_len
    positions = jnp.arange(T, dtype=jnp.int32)[None, :] + offset
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = L.apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]

    if cache is None:
        # train/prefill: reconstruct full k, v
        k_nope = jnp.einsum("btc,chk->bthk", c_kv, p["w_uk"]["kernel"])
        v = jnp.einsum("btc,chk->bthk", c_kv, p["w_uv"]["kernel"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, T, H, qr))],
            axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = attend(qf, k, v, causal=True, lowp=cfg.attn_lowp_probs)
        out = L.dense_in3_apply(p["wo"], o)
        return out, None

    # decode: absorbed form over the compressed cache
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), kv_len, axis=1)
    ckr = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), kv_len, axis=1)
    new_cache = {"c_kv": ckv, "k_rope": ckr}
    S = ckv.shape[1]
    # absorb W_uk into q: q' (B,T,H,kv_lora)
    q_abs = jnp.einsum("bthk,chk->bthc", q_nope.astype(jnp.float32),
                       p["w_uk"]["kernel"].astype(jnp.float32))
    s = (jnp.einsum("bthc,bsc->bhts", q_abs, ckv.astype(jnp.float32)) +
         jnp.einsum("bthr,bsr->bhts", q_rope.astype(jnp.float32),
                    ckr.astype(jnp.float32))) * scale
    k_pos = jnp.arange(S, dtype=jnp.int32)
    q_pos = jnp.arange(T, dtype=jnp.int32) + kv_len
    s = s + _mask_bias(q_pos, k_pos, causal=True, window=0, kv_len=kv_len + T)
    pr = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhts,bsc->bthc", pr, ckv.astype(jnp.float32))
    o = jnp.einsum("bthc,chk->bthk", o_c, p["w_uv"]["kernel"].astype(jnp.float32))
    out = L.dense_in3_apply(p["wo"], o.astype(x.dtype))
    return out, new_cache


def make_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }
