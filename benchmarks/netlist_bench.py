"""Netlist-exact vs analytic GA generation cost (the PR's acceptance bench).

A GA generation = one `batch_eval.evaluate_population` call over a fresh
population (QAT finetune + compile + score + price). The same populations
are evaluated twice — once with the default netlist-exact objective (every
candidate's compiled circuit packed and simulated over the test set in one
launch through `repro.kernels.netlist_sim`) and once with the analytic
float emulation (``netlist=False``) — after one untimed warm-up pass per
mode so XLA traces and the population-sim executables are already built,
which is the steady state a real search runs in.

Acceptance (asserted): warm netlist-exact generations cost <= 2x the
analytic objective on CPU. That is the whole point of the batched kernel —
per-candidate `Simulator` jit launches made bit-exact scoring ~10-100x a
generation; one shape-bucketed executable across the population brings it
inside the 2x envelope, cheap enough to be the default objective.

Second acceptance (asserted): ZERO XLA backend compiles during the timed
warm generations — the untimed warm-up pass is the bounded set that builds
every bucketed executable, and "warm generations reuse executables" is a
counted invariant (via the `repro.obs.xprof` backend-compile listener, no
tracing required), not a belief. A regression that perturbs a static
shape key (population bucket, wave count, batch tile) shows up here as a
nonzero compile count before it shows up as a 2x-ratio breach.
"""
from __future__ import annotations

import statistics
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.configs.printed_mlp import PRINTED_MLPS
from repro.core import batch_eval as BE
from repro.core.compression_spec import ModelMin
from repro.obs import xprof

MAX_RATIO = 2.0

_BITS = (3, 4, 5, 6, 8)
_SPARSITY = (0.0, 0.2, 0.4)
_CLUSTERS = (None, 8, 16)


def _populations(cfg, population: int, generations: int,
                 seed: int) -> List[List[ModelMin]]:
    """Seeded GA-like populations: distinct spec mixes per generation, same
    layer shapes throughout (the executable-reuse regime of a search)."""
    r = np.random.default_rng(seed)
    n_layers = len(cfg.layer_dims) - 1
    gens = []
    for _ in range(generations):
        gens.append([ModelMin.uniform(
            n_layers, bits=int(r.choice(_BITS)),
            sparsity=float(r.choice(_SPARSITY)),
            clusters=_CLUSTERS[int(r.integers(len(_CLUSTERS)))],
            input_bits=cfg.input_bits) for _ in range(population)])
    return gens


def _time_generations(cfg, gens, *, epochs: int,
                      netlist: bool) -> Tuple[float, int]:
    """-> (median wall-clock of one warm generation in ms, backend
    compiles observed during the timed generations — 0 when warm).

    The whole generation list runs once untimed first: spec mixes differ
    per generation, so the population-sim executables specialize on a few
    bucketed shapes (max candidate size, wave count) that only all exist
    after every mix has been seen once — the steady state of a long
    search, where new bucket shapes stop appearing after the first few
    generations. The timed second pass then measures pure warm cost, with
    the xprof compile listener counting any executable XLA still builds."""
    for specs in gens:
        BE.evaluate_population(cfg, specs, epochs=epochs, netlist=netlist)
    times = []
    with xprof.count_compiles() as cc:
        for specs in gens:
            t0 = time.perf_counter()
            BE.evaluate_population(cfg, specs, epochs=epochs,
                                   netlist=netlist)
            times.append((time.perf_counter() - t0) * 1e3)
    return statistics.median(times), cc.compiles


def run(datasets=None, *, population: int = 10, generations: int = 3,
        epochs: int = 60, seed: int = 0) -> List[Dict]:
    rows = []
    for name in (datasets or ["seeds", "whitewine"]):
        cfg = PRINTED_MLPS[name]
        gens = _populations(cfg, population, generations, seed)
        analytic_ms, analytic_compiles = _time_generations(
            cfg, gens, epochs=epochs, netlist=False)
        netlist_ms, netlist_compiles = _time_generations(
            cfg, gens, epochs=epochs, netlist=True)
        rows.append({
            "dataset": name, "population": population, "epochs": epochs,
            "analytic_ms": analytic_ms, "netlist_ms": netlist_ms,
            "ratio": netlist_ms / max(analytic_ms, 1e-9),
            "warm_compiles": analytic_compiles + netlist_compiles,
        })
    return rows


def main(fast: bool = False):
    kw = (dict(datasets=["seeds"], population=6, generations=3, epochs=40)
          if fast else {})
    rows = run(**kw)
    print("netlist_bench (warm GA generation: netlist-exact vs analytic "
          "objective)")
    print("dataset,population,epochs,analytic_gen_ms,netlist_gen_ms,ratio,"
          "warm_compiles")
    ok = True
    cold = 0
    for r in rows:
        print(f"{r['dataset']},{r['population']},{r['epochs']},"
              f"{r['analytic_ms']:.0f},{r['netlist_ms']:.0f},"
              f"{r['ratio']:.2f},{r['warm_compiles']}")
        ok &= r["ratio"] <= MAX_RATIO
        cold += r["warm_compiles"]
    print(f"acceptance (netlist generation <= {MAX_RATIO:.0f}x analytic "
          f"on every row): {'PASS' if ok else 'FAIL'}")
    print("acceptance (zero executables compiled across warm "
          f"generations): {'PASS' if cold == 0 else 'FAIL'}")
    # a FAIL must fail the harness/CI run, not just print
    assert ok, ("netlist-exact generation cost exceeded "
                f"{MAX_RATIO:.0f}x the analytic objective")
    assert cold == 0, (f"{cold} XLA backend compile(s) during warm GA "
                       "generations — a static-shape key is churning "
                       "(bucketing regression); run under REPRO_TRACE=1 "
                       "and read the executables report to find it")
    return rows


if __name__ == "__main__":
    main()
