"""End-to-end printed-MLP minimization demo (the paper, on one dataset).

Walks the full pipeline on the WhiteWine classifier with the batched
population engine:

  1. FP32 pretrain the baseline bespoke MLP (MICRO'20 un-minimized design)
     and price it in printed EGT area/power;
  2. Fig. 1 slice — evaluate a quantization sweep as ONE batched population
     call (every bit width QAT-finetuned in a single vmapped jit);
  3. Fig. 2 — the hardware-aware NSGA-II over bits x sparsity x clusters,
     every generation evaluated through `core.batch_eval`, with the
     persistent on-disk cache so a re-run costs nothing;
  4. report the Pareto front and the area gain at <=5% accuracy loss
     (paper: up to ~8x for the combined search).

Run:  PYTHONPATH=src python examples/printed_mlp_minimization.py
      (add --full for the paper-sized budget)
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.configs.printed_mlp import PRINTED_MLPS
from repro.core import batch_eval as BE
from repro.core import minimize as MZ
from repro.core.compression_spec import ModelMin
from repro.core.pareto import gain_at_loss, pareto_front


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="whitewine",
                    choices=sorted(PRINTED_MLPS))
    ap.add_argument("--full", action="store_true",
                    help="paper-sized budget (slower)")
    ap.add_argument("--cache-dir", default=".eval_cache",
                    help="persistent evaluation cache dir (stable default "
                         "so a re-run retrains nothing)")
    args = ap.parse_args(argv)

    cfg = PRINTED_MLPS[args.dataset]
    n_layers = len(cfg.layer_dims) - 1
    epochs = 90 if args.full else 60
    cache_dir = args.cache_dir
    cache = BE.EvalCache(f"{cache_dir}/{cfg.name}_evals.json")

    # -- 1. baseline ------------------------------------------------------
    t0 = time.time()
    base = MZ.baseline(cfg)
    print(f"[{cfg.name}] baseline (dense 8-bit bespoke): "
          f"acc={base.accuracy:.3f} area={base.area_mm2/100:.1f} cm2 "
          f"power={base.power_mw:.1f} mW "
          f"({base.n_multipliers} multipliers)  [{time.time()-t0:.0f}s]")

    # -- 2. Fig. 1 slice: quantization sweep as one batched call ----------
    t0 = time.time()
    sweep = [ModelMin.uniform(n_layers, bits=b, input_bits=cfg.input_bits)
             for b in range(2, 8)]
    results = BE.evaluate_population(cfg, sweep, epochs=epochs, cache=cache)
    print(f"quantization sweep (one batched call, {len(sweep)} specs, "
          f"{time.time()-t0:.0f}s):")
    for r in results:
        gain = base.area_mm2 / max(r.area_mm2, 1e-9)
        print(f"  {r.spec.layers[0].bits}-bit: acc={r.accuracy:.3f} "
              f"area={r.area_mm2/100:6.2f} cm2 ({gain:.1f}x)")

    # -- 3. Fig. 2: hardware-aware GA through the batched engine ----------
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks import fig2_combined
    t0 = time.time()
    res = fig2_combined.run(
        args.dataset, cache_dir=cache_dir, epochs=epochs,
        **({} if args.full else dict(population=8, generations=3)))
    print(f"GA search: {res['n_evaluations']} unique evaluations in "
          f"{time.time()-t0:.0f}s (cache: {cache_dir})")

    # -- 4. report --------------------------------------------------------
    print(f"combined gain at <=5% accuracy loss: "
          f"{res['combined_gain_at_5pct']:.2f}x (paper: up to ~8x)")
    print("pareto front (acc, area cm2, critical path, spec):")
    for acc, area, delay, spec in res["pareto_front"][:8]:
        print(f"  acc={acc:.3f} area={area/100:7.2f} cm2 "
              f"delay={delay:3d} stages  {spec}")

    # -- 5. compile the chosen point to an actual bespoke circuit ---------
    # pick the cheapest front member within 5% accuracy loss of the
    # baseline (the paper's max-gain operating point) and materialize it:
    # netlist, bit-exact simulated accuracy, structural-vs-analytic
    # pricing, delay
    from repro import circuit
    eligible = [(acc, area, spec) for acc, area, _, spec
                in res["pareto_front"] if acc >= base.accuracy - 0.05]
    if eligible:
        chosen = min(eligible, key=lambda t: t[1])[2]   # cheapest eligible
    else:
        chosen = max(res["pareto_front"], key=lambda t: t[0])[3]
    spec = ModelMin.from_json(chosen)
    net, compiled = circuit.compile_spec(cfg, spec, epochs=epochs)
    _, _, xte, yte = MZ.dataset_for(cfg)
    sc = circuit.structural_cost(net)
    cv = circuit.cross_validate(net, compiled)
    acc_exact = circuit.netlist_accuracy(net, compiled, xte, yte)
    print(f"\ncompiled circuit for the chosen point {chosen}:")
    print(circuit.describe(net, sc))
    print(f"netlist-exact accuracy: {acc_exact:.3f} "
          f"(float emulation: {MZ.compiled_accuracy(compiled, xte, yte):.3f})")
    print(f"structural cost == analytic hw_model: {cv['ok']}")

    # -- 6. approximate the circuit itself under an error budget ----------
    # beyond minimization: the approx pass pipeline (truncated-CSD
    # coefficients, accumulator LSB truncation, comparator narrowing)
    # greedily trades PROVEN worst-case logit error for area
    from repro import approx
    budget = approx.logit_budget(net, 0.01)       # 1% of the logit range
    _, anet, rep = approx.fit_budget(net, budget)
    acc_approx = circuit.netlist_accuracy(anet, compiled, xte, yte)
    asc = circuit.structural_cost(anet)
    print(f"\napproximated under a {budget}-LSB logit-error budget "
          f"(proven bound: {rep.bound}):")
    print(f"  knobs: {rep.params}")
    print(f"  area {sc.area_mm2/100:.2f} -> {asc.area_mm2/100:.2f} cm2 "
          f"({rep.area_gain:.2f}x on top of minimization), "
          f"accuracy {acc_exact:.3f} -> {acc_approx:.3f}")
    return res


if __name__ == "__main__":
    main()
