"""Population netlist simulation: one launch for P candidates x B samples.

Why this exists (the repo's slowest path, measured): per-candidate
`circuit.simulate.Simulator` builds a fresh jitted executable per netlist —
~1-2 s of trace+compile each against ~ms of actual integer compute, so a
16-candidate GA generation under ``netlist=True`` was ~25 s of pure XLA
compilation. Here the *whole population* runs through one shape-stable
executable; shapes are bucketed to powers of two so GA generations reuse
executables instead of retracing.

Two engines, one packing, one oracle:

* ``"levels"`` (default off-TPU) — a host-built global wave schedule over
  the concatenated node tables, executed as ONE ``lax.scan`` over fixed
  (window,)-wide waves with branchless opcode dispatch. Each global
  topological level is chunked into ceil(count/window) waves; every wave of
  level l-1 precedes every wave of level l, so intra-wave independence is
  inherited from the level structure. Padding lanes carry ``op = NOP`` and
  scatter to a dummy slot.
* ``"pallas"`` (default on TPU, int32-width populations) — the bespoke
  kernel in `kernel.py`: grid over candidates x input tiles, levels
  unrolled inside the kernel. Runs interpret=True off-TPU like the other
  five kernels.

Lane width is the verifier's per-node bound maximized over the population:
int32 when every word fits 32 bits, else int64 under a local ``enable_x64``
scope (`repro.verify.netlist.fits_int32` semantics). Both engines are
bit-exact against `circuit.simulate.simulate` and the NumPy oracle in
`ref.py` — tested on all four datasets.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.circuit import ir
from repro.kernels.netlist_sim.kernel import netlist_sim_pallas
from repro.kernels.netlist_sim.pack import (NOP, PackedPopulation,
                                            pack_netlist, pack_population)
from repro.kernels.netlist_sim.ref import (_normalize_x,
                                           simulate_population_ref)
from repro.obs import metrics as MT
from repro.obs import prof as PF
from repro.obs import trace as TR

_CONST = int(ir.Op.CONST)
_SHL = int(ir.Op.SHL)
_ADD = int(ir.Op.ADD)
_SUB = int(ir.Op.SUB)
_NEG = int(ir.Op.NEG)
_RELU = int(ir.Op.RELU)
_ARGMAX = int(ir.Op.ARGMAX)


def _bucket(n: int) -> int:
    """Next power of two >= n (>= 1): the jit specializes on shapes, and
    bucketing keeps one executable per bucket across GA generations."""
    return 1 << (max(int(n), 1) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class _Schedule:
    """Host-derived global wave schedule (all arrays numpy, jnp-ready)."""
    OP: np.ndarray        # (n_waves, W) int32, NOP on padding lanes
    AI: np.ndarray        # (n_waves, W) int32 global operand positions
    BI: np.ndarray        # (n_waves, W) int32
    SH: np.ndarray        # (n_waves, W) int32 immediates (0 elsewhere)
    OUT: np.ndarray       # (n_waves, W) int32 global out positions
    vals0: np.ndarray     # (N_buf,) int64 CONST-seeded initial buffer
    inp_cols: np.ndarray  # (P, n_in) int32 global input positions
    am_cols: np.ndarray   # (P, C) int32 global comparator-operand positions
    n_waves: int          # real (pre-bucket) wave count


def _global_schedule(pop: PackedPopulation, window: int) -> _Schedule:
    """Concatenate the population's tables into one flat position space
    (candidate p's slot s lives at ``off[p] + s``) and chunk each global
    level into fixed-width waves. All vectorized numpy — no per-node
    python loop."""
    P, N = pop.op.shape
    n = pop.n_nodes.astype(np.int64)
    off = np.zeros(P, np.int64)
    off[1:] = np.cumsum(n)[:-1]
    total = int(n.sum())
    slot = np.arange(N, dtype=np.int64)
    valid = slot[None, :] < n[:, None]                    # (P, N)
    gpos = slot[None, :] + off[:, None]                   # (P, N)
    lvls = np.zeros((P, N), np.int64)
    for p in range(P):
        ptr = pop.level_ptr[p].astype(np.int64)
        lvls[p, :n[p]] = np.repeat(np.arange(ptr.size - 1), np.diff(ptr))

    comp = valid & (pop.op >= _SHL) & (pop.op != _ARGMAX)
    op_c = pop.op[comp].astype(np.int64)
    a_c = (pop.arg_a + off[:, None])[comp]
    b_c = (pop.arg_b + off[:, None])[comp]
    sh_c = pop.shift[comp].astype(np.int64)
    out_c = gpos[comp]
    lv_c = lvls[comp]

    ordr = np.argsort(lv_c, kind="stable")
    op_s, a_s, b_s = op_c[ordr], a_c[ordr], b_c[ordr]
    sh_s, out_s, lv_s = sh_c[ordr], out_c[ordr], lv_c[ordr]
    M = op_s.size

    counts = np.bincount(lv_s) if M else np.zeros(1, np.int64)
    wins = -(-counts // window)                           # ceil per level
    wstart = np.concatenate([[0], np.cumsum(wins)])
    lfirst = np.concatenate([[0], np.cumsum(counts)])
    rank = np.arange(M) - lfirst[lv_s]
    row = wstart[lv_s] + rank // window
    col = rank % window

    nw = _bucket(int(wstart[-1]))
    n_buf = _bucket(total + 1)                            # +1: dummy slot
    dummy = n_buf - 1
    OP = np.full((nw, window), NOP, np.int32)
    AI = np.zeros((nw, window), np.int32)
    BI = np.zeros((nw, window), np.int32)
    SH = np.zeros((nw, window), np.int32)
    OUT = np.full((nw, window), dummy, np.int32)
    OP[row, col] = op_s
    AI[row, col] = a_s
    BI[row, col] = b_s
    SH[row, col] = sh_s
    OUT[row, col] = out_s

    vals0 = np.zeros(n_buf, np.int64)
    cmask = valid & (pop.op == _CONST)
    vals0[gpos[cmask]] = pop.val[cmask]
    return _Schedule(
        OP=OP, AI=AI, BI=BI, SH=SH, OUT=OUT, vals0=vals0,
        inp_cols=(pop.input_pos + off[:, None]).astype(np.int32),
        am_cols=(pop.argmax_pos + off[:, None]).astype(np.int32),
        n_waves=int(wstart[-1]))


@jax.jit
def _run_levels(OP, AI, BI, SH, OUT, vals0, inp_cols, am_cols, x):
    """x: (B, P*n_in) already in the lane dtype. -> (B, P, C) comparator
    operands. One scan over waves; every lane dispatches branchlessly on
    its opcode (padding lanes fall through to the TRUNC arm with shift 0
    and scatter to the dummy slot)."""
    B = x.shape[0]
    vals = jnp.tile(vals0[None, :], (B, 1))
    vals = vals.at[:, inp_cols.reshape(-1)].set(x)

    def step(vals, wave):
        o, ai, bi, sh, out = wave
        a = jnp.take(vals, ai, axis=1)
        b = jnp.take(vals, bi, axis=1)
        r = jnp.where(o == _SHL, jnp.left_shift(a, sh),
            jnp.where(o == _ADD, a + b,
            jnp.where(o == _SUB, a - b,
            jnp.where(o == _NEG, -a,
            jnp.where(o == _RELU, jnp.maximum(a, 0),
                      # TRUNC (and NOP padding, with sh = 0)
                      jnp.left_shift(jnp.right_shift(a, sh), sh))))))
        return vals.at[:, out].set(r), None

    vals, _ = jax.lax.scan(step, vals, (OP, AI, BI, SH, OUT))
    return jnp.take(vals, am_cols, axis=1)                # (B, P, C)


def _pad_candidates(pop: PackedPopulation, x: np.ndarray, p_pad: int):
    """Repeat candidate 0 up to the population bucket so the executable
    specializes on bucketed shapes only."""
    reps = p_pad - pop.n_candidates
    if reps <= 0:
        return pop, x
    tile2 = lambda a: np.concatenate([a, np.repeat(a[:1], reps, 0)])  # noqa: E731
    pop2 = PackedPopulation(
        op=tile2(pop.op), arg_a=tile2(pop.arg_a), arg_b=tile2(pop.arg_b),
        shift=tile2(pop.shift), val=tile2(pop.val),
        orig_id=tile2(pop.orig_id), level_ptr=tile2(pop.level_ptr),
        input_pos=tile2(pop.input_pos), argmax_pos=tile2(pop.argmax_pos),
        n_nodes=tile2(pop.n_nodes), n_levels=tile2(pop.n_levels),
        max_width=pop.max_width)
    return pop2, tile2(x)


def _real_ops(pop: PackedPopulation) -> int:
    """Computational (wave-scheduled) ops over the real candidates."""
    n = pop.n_nodes.astype(np.int64)
    valid = np.arange(pop.op.shape[1])[None, :] < n[:, None]
    return int((valid & (pop.op >= _SHL) & (pop.op != _ARGMAX)).sum())


def _run_engine(pop: PackedPopulation, x: np.ndarray, engine: str,
                window: int, block_b: int,
                interpret: Optional[bool]) -> Tuple[np.ndarray, Dict]:
    """-> (amx, stats): amx (P, B, C) int64 for the real (unpadded)
    candidates; stats the launch's padding/executable accounting —
    ``key`` is the *exact* static-shape specialization tuple of the jit
    this launch dispatches (the executable-observatory identity), and
    the ``*_used``/``*_total`` pairs measure real work vs padded work
    (NOP wave lanes, repeated candidates, repeated batch rows)."""
    P, B = x.shape[0], x.shape[1]
    fits32 = pop.max_width <= 32
    scope = contextlib.nullcontext() if fits32 else enable_x64()
    dtype = jnp.int32 if fits32 else jnp.int64
    lane = "int32" if fits32 else "int64"

    if engine == "pallas":
        if not fits32:
            # TPU Pallas has no int64 lanes — wide populations take the
            # levels engine whatever the caller asked for
            engine = "levels"
        elif interpret is None:
            interpret = jax.default_backend() != "tpu"

    if engine == "levels":
        p_pad = _bucket(P)
        ppad, xpad = _pad_candidates(pop, x, p_pad)
        sched = _global_schedule(ppad, window)
        bt = min(_bucket(B), block_b)
        n_tiles = -(-B // bt)
        nw, W = sched.OP.shape
        n_buf = sched.vals0.size
        n_in, C = sched.inp_cols.shape[1], sched.am_cols.shape[1]
        stats = {
            "engine": "levels",
            "key": ("netlist_levels", nw, W, n_buf, p_pad, n_in, C, bt,
                    lane),
            "cand_real": P, "cand_total": p_pad,
            # wave lanes actually carrying an op (incl. the repeated
            # padding candidates) vs the bucketed wave grid
            "lanes_used": int((sched.OP != NOP).sum()),
            "lanes_total": nw * W,
            "ops_real": _real_ops(pop),
            "rows_real": B, "rows_total": n_tiles * bt,
            "tiles": n_tiles,
        }

        def _lower():
            tile = xc[0:bt]
            pad = bt - tile.shape[0]
            if pad:
                tile = np.concatenate([tile, tile[-1:].repeat(pad, 0)])
            with (contextlib.nullcontext() if fits32 else enable_x64()):
                return _run_levels.lower(*args, vals0, inp_cols, am_cols,
                                         jnp.asarray(tile.astype(dtype)))

        outs = []
        ctx = (PF.dispatch("kernels.netlist_sim.levels", stats["key"],
                           lower=_lower, p=P, b=B, tiles=n_tiles)
               if TR.active() else contextlib.nullcontext())
        with ctx, scope:
            args = [jnp.asarray(a) for a in
                    (sched.OP, sched.AI, sched.BI, sched.SH, sched.OUT)]
            vals0 = jnp.asarray(sched.vals0.astype(dtype))
            inp_cols = jnp.asarray(sched.inp_cols)
            am_cols = jnp.asarray(sched.am_cols)
            # (P, B, n_in) -> (B, P*n_in) columns in global-position order
            xc = np.ascontiguousarray(
                xpad.transpose(1, 0, 2).reshape(B, -1))
            for b0 in range(0, B, bt):
                tile = xc[b0:b0 + bt]
                pad = bt - tile.shape[0]
                if pad:
                    tile = np.concatenate([tile, tile[-1:].repeat(pad, 0)])
                amx = _run_levels(*args, vals0, inp_cols, am_cols,
                                  jnp.asarray(tile.astype(dtype)))
                outs.append(np.asarray(amx[:bt - pad], np.int64))
        amx = np.concatenate(outs).transpose(1, 0, 2)     # (P_pad, B, C)
        return amx[:P], stats

    if engine == "pallas":
        bt = min(_bucket(B), 256)
        bpad = -B % bt
        xp = (np.concatenate([x, x[:, -1:].repeat(bpad, 1)], axis=1)
              if bpad else x)
        N, Lp1 = pop.op.shape[1], pop.level_ptr.shape[1]
        n_in, C = pop.input_pos.shape[1], pop.argmax_pos.shape[1]
        slots_used = int(pop.n_nodes.sum())
        stats = {
            "engine": "pallas",
            "key": ("netlist_pallas", P, N, Lp1, n_in, C, B + bpad, bt,
                    bool(interpret)),
            "cand_real": P, "cand_total": P,
            # dense (P, N) node tables vs the candidates' real node counts
            "lanes_used": slots_used, "lanes_total": P * N,
            "ops_real": _real_ops(pop),
            "rows_real": B, "rows_total": B + bpad,
            "tiles": (B + bpad) // bt,
        }
        tables = (jnp.asarray(pop.op), jnp.asarray(pop.arg_a),
                  jnp.asarray(pop.arg_b), jnp.asarray(pop.shift),
                  jnp.asarray(pop.val.astype(np.int32)),
                  jnp.asarray(pop.level_ptr), jnp.asarray(pop.input_pos),
                  jnp.asarray(pop.argmax_pos),
                  jnp.asarray(xp.astype(np.int32)))

        def _lower():
            fn = jax.jit(functools.partial(netlist_sim_pallas, block_b=bt,
                                           interpret=bool(interpret)))
            return fn.lower(*tables)

        ctx = (PF.dispatch("kernels.netlist_sim.pallas", stats["key"],
                           lower=_lower, p=P, b=B, tiles=stats["tiles"])
               if TR.active() else contextlib.nullcontext())
        with ctx:
            amx = netlist_sim_pallas(*tables, block_b=bt,
                                     interpret=bool(interpret))
            jax.block_until_ready(amx)
        return np.asarray(amx, np.int64)[:, :B], stats

    raise ValueError(f"unknown engine {engine!r}")


def simulate_population(pop: PackedPopulation, x: np.ndarray, *,
                        engine: Optional[str] = None, window: int = 256,
                        block_b: int = 2048,
                        interpret: Optional[bool] = None
                        ) -> Dict[str, np.ndarray]:
    """Simulate P packed candidates over a batch in one launch.

    x: (B, n_in) shared inputs or (P, B, n_in) per-candidate (candidates
    quantizing the ADC lanes at different ``input_bits`` need their own
    integer features). engine: "levels" | "pallas" | "ref" | None
    (auto per `repro.configs.backend.default_netlist_engine`).

    -> {"amx": (P, B, C) int64 comparator operands,
        "argmax": (P, B) int64 class decisions} — bit-exact vs
    `circuit.simulate.simulate` per candidate.
    """
    x = np.asarray(_normalize_x(pop, x))
    if engine is None:
        from repro.configs import backend
        engine = backend.default_netlist_engine()
    if engine == "ref":
        return simulate_population_ref(pop, x)

    P, B = x.shape[0], x.shape[1]
    MT.counter("netlist_sim.launches").inc()
    MT.counter("netlist_sim.candidates").inc(P)
    if not TR.active():
        amx, stats = _run_engine(pop, x, engine, window, block_b, interpret)
    else:
        with TR.span("kernels.netlist_sim", engine=engine, p=P, b=B,
                     slots=int(pop.n_nodes.sum())):
            amx, stats = _run_engine(pop, x, engine, window, block_b,
                                     interpret)
    _account_padding(stats)
    return {"amx": amx, "argmax": np.argmax(amx, axis=-1).astype(np.int64)}


def _account_padding(stats: Dict) -> None:
    """Always-on packing-efficiency accounting for one launch. Counters
    hold exact lane/row totals (deterministic functions of the evaluated
    populations, so they keep the checkpoint bit-identity contract);
    utilization ratios go to gauges/histograms; the full per-launch stats
    ride the trace as a ``netlist_sim.padding`` event when tracing."""
    lanes_u, lanes_t = stats["lanes_used"], stats["lanes_total"]
    rows_r, rows_t = stats["rows_real"], stats["rows_total"]
    MT.counter("netlist_sim.pad.lanes_used").inc(lanes_u)
    MT.counter("netlist_sim.pad.lanes_total").inc(lanes_t)
    MT.counter("netlist_sim.pad.rows_real").inc(rows_r)
    MT.counter("netlist_sim.pad.rows_total").inc(rows_t)
    MT.counter("netlist_sim.pad.cand_real").inc(stats["cand_real"])
    MT.counter("netlist_sim.pad.cand_total").inc(stats["cand_total"])
    lane_util = lanes_u / max(lanes_t, 1)
    MT.gauge("netlist_sim.lane_util").set(lane_util)
    MT.histogram("netlist_sim.lane_util_hist").observe(lane_util)
    MT.histogram("netlist_sim.row_util_hist").observe(
        rows_r / max(rows_t, 1))
    if TR.active():
        TR.event("netlist_sim.padding",
                 **{k: (PF.key_str(v) if k == "key" else v)
                    for k, v in stats.items()})


def population_accuracy(pop: PackedPopulation, x: np.ndarray,
                        y: np.ndarray, **kw) -> np.ndarray:
    """Netlist-exact test accuracy per candidate: -> (P,) float64. ``x``
    must already be ADC-quantized integers (see
    `minimize.quantize_inputs`)."""
    cls = simulate_population(pop, x, **kw)["argmax"]
    return np.mean(cls == np.asarray(y)[None, :], axis=1)


__all__ = ["simulate_population", "population_accuracy", "pack_netlist",
           "pack_population", "simulate_population_ref"]
