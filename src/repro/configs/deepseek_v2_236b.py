"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed top-6.
[arXiv:2405.04434]"""
from repro.configs.base import (ArchConfig, LayerSpec, MLAConfig, MoEConfig,
                                Segment)

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    d_model=5120,
    vocab_size=102400,
    # layer 0: dense FFN (intermediate 12288); layers 1..59: MoE
    segments=(
        Segment((LayerSpec("attn", "dense"),), 1),
        Segment((LayerSpec("attn", "moe"),), 59),
    ),
    num_heads=128,
    num_kv_heads=128,                  # MLA reconstructs per-head k/v
    head_dim=192,                      # qk_nope 128 + rope 64
    d_ff=12288,                        # dense layer intermediate
    mlp_type="swiglu",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, d_expert=1536,
                  num_shared_experts=2, d_shared=3072,
                  capacity_factor=1.25),
    rope_theta=10000.0,
    source="arXiv:2405.04434; hf",
    notes="decode uses the absorbed MLA form over the compressed c_kv cache",
)
