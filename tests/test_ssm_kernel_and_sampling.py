"""ssm_scan Pallas kernel (interpret mode) vs the model's selective scan,
plus sampling strategy tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssm_scan import ssm_scan, ssm_scan_ref
from repro.serve import sampling as S

KEY = jax.random.PRNGKey(0)


def _ssm_inputs(B=2, T=24, d=32, N=4, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    u = jax.random.normal(ks[0], (B, T, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, d)) - 1.0)
    B_ = jax.random.normal(ks[2], (B, T, N))
    C_ = jax.random.normal(ks[3], (B, T, N))
    A = -jnp.exp(jax.random.normal(ks[4], (d, N)) * 0.3)
    D = jax.random.normal(ks[5], (d,))
    return u, dt, B_, C_, A, D


@pytest.mark.parametrize("B,T,d,N", [(1, 16, 32, 4), (2, 24, 64, 8),
                                     (1, 20, 48, 16)])
def test_ssm_scan_matches_model_scan(B, T, d, N):
    u, dt, B_, C_, A, D = _ssm_inputs(B, T, d, N)
    y_k = ssm_scan(u, dt, B_, C_, A, D, block_d=16, block_t=4)
    y_r = ssm_scan_ref(u, dt, B_, C_, A, D)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)


def test_ssm_scan_ragged_time():
    """T not divisible by block_t: padded internally, result exact."""
    u, dt, B_, C_, A, D = _ssm_inputs(T=19)
    y_k = ssm_scan(u, dt, B_, C_, A, D, block_d=16, block_t=8)
    y_r = ssm_scan_ref(u, dt, B_, C_, A, D)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)


def test_ssm_scan_state_carries_across_time_blocks():
    """Recurrence must flow across T-grid boundaries: output at t depends on
    inputs before the current time block."""
    u, dt, B_, C_, A, D = _ssm_inputs(T=16)
    y1 = ssm_scan(u, dt, B_, C_, A, D, block_d=16, block_t=4)
    u2 = u.at[:, 0].set(u[:, 0] + 10.0)
    y2 = ssm_scan(u2, dt, B_, C_, A, D, block_d=16, block_t=4)
    # far-future outputs must differ (the state carried the perturbation)
    assert float(jnp.max(jnp.abs(y1[:, 12:] - y2[:, 12:]))) > 1e-6


def test_ssm_scan_bf16_inputs():
    u, dt, B_, C_, A, D = _ssm_inputs(T=16)
    y_k = ssm_scan(u.astype(jnp.bfloat16), dt, B_, C_, A, D,
                   block_d=16, block_t=4)
    y_r = ssm_scan_ref(u, dt, B_, C_, A, D)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------


def test_greedy_matches_argmax():
    logits = jax.random.normal(KEY, (4, 100))
    np.testing.assert_array_equal(np.asarray(S.greedy(logits)),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_temperature_zero_is_greedy():
    logits = jax.random.normal(KEY, (4, 50))
    np.testing.assert_array_equal(
        np.asarray(S.temperature(KEY, logits, t=0.0)),
        np.asarray(S.greedy(logits)))


def test_top_k_support():
    logits = jnp.asarray([[0.0, 1.0, 2.0, 3.0, 4.0]])
    for seed in range(20):
        tok = int(S.top_k(jax.random.PRNGKey(seed), logits, k=2, t=1.0)[0])
        assert tok in (3, 4)


def test_top_p_support():
    logits = jnp.log(jnp.asarray([[0.5, 0.3, 0.1, 0.05, 0.05]]))
    for seed in range(20):
        tok = int(S.top_p(jax.random.PRNGKey(seed), logits, p=0.7)[0])
        assert tok in (0, 1)


def test_low_temperature_concentrates():
    logits = jax.random.normal(KEY, (1, 64)) * 3
    hot = set(int(S.temperature(jax.random.PRNGKey(s), logits, 0.05)[0])
              for s in range(10))
    assert len(hot) <= 2
