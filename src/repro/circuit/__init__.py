"""Bespoke circuit compiler: netlist IR, bit-exact simulation, structural
cost.

The analytic printed-area model (`repro.core.hw_model`) prices bespoke
circuits from coefficient statistics; this package *builds* those circuits:

* `repro.circuit.ir`        — typed integer netlist IR with derived widths
* `repro.circuit.compile`   — QAT compile output -> CSD shift-add netlist
* `repro.circuit.simulate`  — level-batched, jitted, vmapped exact eval
* `repro.circuit.cost`      — structural area/power (cross-validates
                              hw_model exactly) + critical-path delay

Quick use::

    net, compiled = circuit.compile_spec(cfg, spec, epochs=60)
    acc = circuit.netlist_accuracy(net, compiled, xte, yte)
    sc = circuit.structural_cost(net)           # area/power/delay
    print(circuit.describe(net, sc))
"""
from repro.circuit import compile, cost, ir, simulate  # noqa: F401
from repro.circuit.compile import compile_netlist, compile_spec  # noqa: F401
from repro.circuit.cost import (DELAY_FA_MS, StructuralCost,  # noqa: F401
                                cross_validate, describe, structural_cost)
from repro.circuit.ir import Netlist, Node, Op  # noqa: F401
from repro.circuit.simulate import (Simulator, netlist_accuracy,  # noqa: F401
                                    simulate)
