"""jit'd wrapper with padding + interpret fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.clustered_matmul.kernel import clustered_matmul_pallas
from repro.kernels.clustered_matmul.ref import clustered_matmul_ref
from repro.obs import prof as PF
from repro.obs import trace as TR


def _pad_to(a, mult, axis, value=0):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def _clustered_matmul_jit(x, idx, codebook, *, block_m, block_n, block_k,
                          interpret):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    M, N = x.shape[0], idx.shape[1]
    xp = _pad_to(_pad_to(x, block_m, 0), block_k, 1)
    # padded K rows index cluster 0 of a zero codebook row -> contribute 0
    ip = _pad_to(_pad_to(idx, block_k, 0), block_n, 1)
    cp = _pad_to(codebook, block_k, 0)
    y = clustered_matmul_pallas(xp, ip, cp, block_m=block_m, block_n=block_n,
                                block_k=block_k, interpret=interpret)
    return y[:M, :N]


def clustered_matmul(x, idx, codebook, *, block_m=128, block_n=128,
                     block_k=128, interpret: bool | None = None):
    if not TR.active():
        return _clustered_matmul_jit(x, idx, codebook, block_m=block_m,
                                     block_n=block_n, block_k=block_k,
                                     interpret=interpret)
    key = ("clustered_matmul", x.shape, idx.shape, block_m, block_n, block_k)
    with PF.dispatch("kernels.clustered_matmul", key,
                     lower=lambda: _clustered_matmul_jit.lower(
                         x, idx, codebook, block_m=block_m, block_n=block_n,
                         block_k=block_k, interpret=interpret),
                     m=x.shape[0], k=x.shape[1], n=idx.shape[1]):
        y = _clustered_matmul_jit(x, idx, codebook, block_m=block_m,
                                  block_n=block_n, block_k=block_k,
                                  interpret=interpret)
        jax.block_until_ready(y)
    return y


__all__ = ["clustered_matmul", "clustered_matmul_ref"]
