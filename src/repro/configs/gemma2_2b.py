"""gemma2-2b [dense] — local/global alternation, logit softcaps, post-norms.
[arXiv:2408.00118]"""
from repro.configs.base import ArchConfig, LayerSpec, Segment

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    d_model=2304,
    vocab_size=256000,
    # 26 layers: (local, global) x 13
    segments=(Segment((LayerSpec("local", "dense"),
                       LayerSpec("attn", "dense")), 13),),
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    mlp_type="geglu",
    window_size=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_norm=True,
    norm_unit_offset=True,
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10000.0,
    source="arXiv:2408.00118; hf",
    notes="global-attention half keeps the arch out of the sub-quadratic "
          "class; long_500k skipped (DESIGN.md §9)",
)
