"""Attention-path invariants: causality (property), banded == masked-dense
sliding window, ring-buffer decode == full-cache decode, chunked == dense."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.nn import attention as A

KEY = jax.random.PRNGKey(0)


def _qkv(B=1, T=32, H=4, KV=2, hd=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, T, H, hd)),
            jax.random.normal(ks[1], (B, T, KV, hd)),
            jax.random.normal(ks[2], (B, T, KV, hd)))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), t_cut=st.integers(1, 30))
def test_property_causality(seed, t_cut):
    """Output at position < t_cut is unchanged by edits to tokens >= t_cut."""
    q, k, v = _qkv(seed=seed)
    o1 = A.attend(q, k, v, causal=True)
    k2 = k.at[:, t_cut:].set(jax.random.normal(KEY, k[:, t_cut:].shape))
    v2 = v.at[:, t_cut:].set(jax.random.normal(KEY, v[:, t_cut:].shape))
    o2 = A.attend(q, k2, v2, causal=True)
    np.testing.assert_allclose(np.asarray(o1[:, :t_cut]),
                               np.asarray(o2[:, :t_cut]), atol=1e-5)


def test_chunked_equals_dense():
    q, k, v = _qkv(T=96)
    dense = A.attend(q, k, v, causal=True, chunk=4096)
    chunked = A.attend(q, k, v, causal=True, chunk=32)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window", [8, 16, 24])
def test_banded_equals_masked_dense(window):
    q, k, v = _qkv(T=60)
    banded = A.attend_local_banded(q, k, v, window=window)
    dense = A.attend(q, k, v, causal=True, window=window, chunk=4096)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)


def test_ring_buffer_matches_full_cache_decode():
    """Sliding-window decode with an O(window) ring buffer must equal decode
    with a full-length cache + window mask, far beyond the buffer length.
    (attn_apply's ring branch triggers only when the buffer length equals the
    window; the full-size buffer exercises the masked-full path.)"""
    from repro.configs import ARCHS
    cfg = ARCHS["gemma2-2b"].reduced(window_size=8)
    p = A.attn_init(jax.random.PRNGKey(3), cfg, jnp.float32)
    B, steps = 2, 30
    ring = A.make_attn_cache(cfg, B, steps, jnp.float32, mixer="local")
    assert ring["k"].shape[1] == cfg.window_size           # O(window)!
    full = A.make_attn_cache(cfg, B, steps, jnp.float32, mixer="attn")
    assert full["k"].shape[1] == steps
    for t in range(steps):
        x = jax.random.normal(jax.random.PRNGKey(100 + t),
                              (B, 1, cfg.d_model))
        o_ring, ring = A.attn_apply(p, x, cfg, mixer="local", cache=ring,
                                    kv_len=jnp.asarray(t))
        o_full, full = A.attn_apply(p, x, cfg, mixer="local", cache=full,
                                    kv_len=jnp.asarray(t))
        np.testing.assert_allclose(np.asarray(o_ring), np.asarray(o_full),
                                   rtol=1e-4, atol=1e-4, err_msg=f"t={t}")


def test_softcap_bounds_scores():
    q, k, v = _qkv(T=16)
    o_plain = A.attend(q * 100, k * 100, v, causal=True)
    o_cap = A.attend(q * 100, k * 100, v, causal=True, softcap=10.0)
    assert bool(jnp.all(jnp.isfinite(o_cap)))
    assert not np.allclose(np.asarray(o_plain), np.asarray(o_cap))


def test_gqa_group_broadcast_matches_repeat():
    """GQA with KV groups == MHA after explicitly repeating kv heads."""
    q, k, v = _qkv(H=4, KV=2)
    o_gqa = A.attend(q, k, v, causal=True)
    k_rep = jnp.repeat(k, 2, axis=2)
    v_rep = jnp.repeat(v, 2, axis=2)
    o_mha = A.attend(q, k_rep, v_rep, causal=True)
    np.testing.assert_allclose(np.asarray(o_gqa), np.asarray(o_mha),
                               rtol=1e-5, atol=1e-5)
