"""Process-wide metrics registry: counters, gauges, histograms.

Metrics are pure in-memory arithmetic — no file, no syscall, ever — so
the registry is always on (unlike tracing, which owns a file handle and
hides behind ``REPRO_TRACE``). The cost of an un-exported counter is one
dict lookup and an integer add.

Determinism contract (the checkpoint/resume invariant):

* **counters** hold exact Python ints and count *deterministic* search
  quantities (specs evaluated/memoized, cache hits, quarantines by stage,
  ejections, migrations). `search.runtime.SearchRuntime` snapshots the
  registry into every checkpoint and ``resume()`` restores it, so a
  preempted+resumed search finishes with counters **bit-identical** to the
  uninterrupted run's (tested).
* **gauges** and **histograms** may hold wall-clock and byte sizes
  (checkpoint write ms/bytes, flush times) — real measurements that
  legitimately differ between a preempted and an uninterrupted run. They
  are snapshotted and restored too, but excluded from the bit-identity
  invariant.

Snapshot layout (JSON-able, keys sorted — byte-stable for equal states)::

    {"counters": {name: int},
     "gauges":   {name: float},
     "histograms": {name: {"count": int, "sum": float,
                            "min": float, "max": float}}}
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

_LOCK = threading.Lock()


class Counter:
    """Monotone integer counter."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += int(n)


class Gauge:
    """Last-write-wins scalar."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming summary (count/sum/min/max) — enough for overhead and
    size accounting without bucket-boundary bikeshedding."""
    __slots__ = ("count", "sum", "min", "max")

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class MetricsRegistry:
    """Named metric store. Get-or-create accessors; snapshot/restore are
    the checkpoint surface."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with _LOCK:
                c = self._counters.setdefault(name, Counter())
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with _LOCK:
                g = self._gauges.setdefault(name, Gauge())
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with _LOCK:
                h = self._histograms.setdefault(name, Histogram())
        return h

    def reset(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._histograms.clear()

    # -- checkpoint surface --------------------------------------------------

    def snapshot(self) -> Dict:
        return {
            "counters": {k: c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "histograms": {k: {"count": h.count, "sum": h.sum,
                               "min": h.min, "max": h.max}
                           for k, h in sorted(self._histograms.items())},
        }

    def restore(self, snap: Optional[Dict]) -> None:
        """Replace state with a snapshot's — exact, so restored counters
        are bit-identical to the values at save time. Tolerates missing
        sections (checkpoints predating the obs layer restore to empty)."""
        self.reset()
        if not snap:
            return
        for k, v in snap.get("counters", {}).items():
            self.counter(k).value = int(v)
        for k, v in snap.get("gauges", {}).items():
            self.gauge(k).value = float(v)
        for k, d in snap.get("histograms", {}).items():
            h = self.histogram(k)
            h.count = int(d["count"])
            h.sum = float(d["sum"])
            h.min = None if d["min"] is None else float(d["min"])
            h.max = None if d["max"] is None else float(d["max"])


# the process-wide registry: search/eval code increments through these
REGISTRY = MetricsRegistry()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str) -> Histogram:
    return REGISTRY.histogram(name)


def snapshot() -> Dict:
    return REGISTRY.snapshot()


def restore(snap: Optional[Dict]) -> None:
    REGISTRY.restore(snap)


__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
           "counter", "gauge", "histogram", "restore", "snapshot"]
