import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  1. FULL compile (layers under lax.scan) on the production mesh — proves the
     sharding config is coherent end-to-end; records memory_analysis().
  2. Depth-reduced UNROLLED lowers (repeats=1 and 1+e_i per depth knob) to fit
     the affine cost model (see repro.roofline.analysis) — XLA cost_analysis
     counts while bodies once, so full-depth FLOPs/bytes/collective-bytes are
     extrapolated exactly from the unrolled variants.
  3. Writes artifacts/dryrun/<arch>__<shape>__<mesh>.json (existing files are
     skipped -> the sweep is resumable / fault tolerant).

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--force]
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, shape_applicable
from repro.configs.base import ArchConfig, Segment, ShapeConfig
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.nn import transformer as T
from repro.roofline import analysis as RA
from repro.roofline.hw import TPU_V5E
from repro.train import train_state as TS
from repro.train.optimizer import AdamWConfig

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# ---------------------------------------------------------------------------
# §Perf variants: named config/serving transforms for the hillclimb cells.
# Each entry: (cfg_transform, serve_weight_bits, kv_cache_dtype)
# ---------------------------------------------------------------------------

VARIANTS = {
    "baseline": (lambda c: c, None, None),
    # bf16 attention scores/probs (halves the dominant HBM score bytes)
    "lowp_attn": (lambda c: dataclasses.replace(c, attn_lowp_probs=True),
                  None, None),
    # save matmul outputs under remat (trade memory for recompute bytes)
    "remat_dots": (lambda c: dataclasses.replace(c, remat_policy="dots"),
                   None, None),
    "lowp_dots": (lambda c: dataclasses.replace(
        c, attn_lowp_probs=True, remat_policy="dots"), None, None),
    # EP-local MoE routing (kills the global token-gather collectives)
    "moe_ps": (lambda c: dataclasses.replace(
        c, moe=dataclasses.replace(c.moe, dispatch="per_sample")), None, None),
    "moe_ps_lowp": (lambda c: dataclasses.replace(
        c, attn_lowp_probs=True,
        moe=dataclasses.replace(c.moe, dispatch="per_sample")), None, None),
    # paper technique on the serving path: intN weights (+ fp8 KV cache)
    "w8": (lambda c: c, 8, None),
    "w4": (lambda c: c, 4, None),
    "w8kv8": (lambda c: c, 8, "float8_e4m3fn"),
    "w4kv8": (lambda c: c, 4, "float8_e4m3fn"),
    # TP-only serving: quantized weights small enough to drop FSDP entirely
    # -> the per-layer weight all-gather disappears (XLA dequantizes shards
    # locally, so sharded intN never shrinks the gather — removing it does)
    "w8tp": (lambda c: c, 8, "float8_e4m3fn"),
    "w4tp": (lambda c: c, 4, "float8_e4m3fn"),
}

NO_FSDP_VARIANTS = {"w8tp", "w4tp"}


# ---------------------------------------------------------------------------
# depth knobs
# ---------------------------------------------------------------------------


def depth_knobs(cfg: ArchConfig):
    """Repeat counts the affine cost model fits over: one per segment, plus
    the encoder stack if present."""
    knobs = [seg.repeats for seg in cfg.segments]
    if cfg.encoder is not None:
        knobs.append(cfg.encoder.num_layers)
    return knobs


def with_depth(cfg: ArchConfig, repeats) -> ArchConfig:
    n_seg = len(cfg.segments)
    segs = tuple(Segment(s.pattern, int(r))
                 for s, r in zip(cfg.segments, repeats[:n_seg]))
    kw = {"segments": segs}
    if cfg.encoder is not None:
        kw["encoder"] = dataclasses.replace(cfg.encoder,
                                            num_layers=int(repeats[n_seg]))
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# lowering one variant
# ---------------------------------------------------------------------------


def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, *, unroll: bool,
               serve_bits=None, kv_dtype=None, fsdp: bool = True):
    """Returns the lowered computation for one cell/variant."""
    opt_cfg = AdamWConfig()
    in_specs = SP.input_specs(cfg, shape)
    in_shard = SP.input_shardings(cfg, shape, mesh)

    # jax.set_mesh is post-0.4.x; `with mesh:` is its 0.4 equivalent (all
    # shardings below are explicit NamedShardings, the context only scopes
    # spec resolution)
    with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
        if shape.kind == "train":
            state_shapes = SP.abstract_train_state(cfg)
            state_shard = SP.train_state_shardings(cfg, mesh, state_shapes)
            step = TS.make_train_step(cfg, opt_cfg, remat=True, unroll=unroll)
            jf = jax.jit(step, in_shardings=(state_shard, in_shard),
                         out_shardings=(state_shard, None),
                         donate_argnums=(0,))
            lowered = jf.lower(state_shapes, in_specs)
        elif shape.kind == "prefill":
            params_shapes = SP.abstract_params(cfg)
            pshard = SP.param_shardings(cfg, mesh, params_shapes)
            step = TS.make_prefill_step(cfg, unroll=unroll)
            jf = jax.jit(step, in_shardings=(pshard, in_shard))
            lowered = jf.lower(params_shapes, in_specs)
        else:  # decode
            from repro.serve import quantized as QS
            params_shapes = SP.abstract_params(cfg)
            dstate = SP.abstract_decode_state(cfg, shape, kv_dtype=kv_dtype)
            dshard = SP.decode_state_shardings(cfg, shape, mesh, dstate)
            if serve_bits:
                pshard, params_shapes = QS.quantized_shardings(
                    cfg, mesh, params_shapes, bits=serve_bits, fsdp=fsdp)
                step = QS.make_quant_serve_step(cfg, unroll=unroll)
            else:
                pshard = SP.param_shardings(cfg, mesh, params_shapes)
                step = TS.make_serve_step(cfg, unroll=unroll)
            jf = jax.jit(step, in_shardings=(pshard, dshard,
                                             in_shard["tokens"]),
                         out_shardings=(None, dshard),
                         donate_argnums=(1,))
            lowered = jf.lower(params_shapes, dstate, in_specs["tokens"])
    return lowered


def measure_variant(cfg, shape, mesh, repeats, *, serve_bits=None,
                    kv_dtype=None, fsdp=True) -> dict:
    from repro.nn import attention as ATT
    v = with_depth(cfg, repeats)
    ATT.CHUNK_OVERRIDE = 1 << 30   # exact-count dense attention (see module)
    try:
        lowered = lower_cell(v, shape, mesh, unroll=True,
                             serve_bits=serve_bits, kv_dtype=kv_dtype,
                             fsdp=fsdp)
        compiled = lowered.compile()
    finally:
        ATT.CHUNK_OVERRIDE = None
    out = RA.cost_dict(compiled)
    out.update({f"coll_{k}": val for k, val in
                RA.collective_bytes(compiled.as_text()).items()})
    return out


# ---------------------------------------------------------------------------
# per-cell driver
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             skip_reduced: bool = False, variant: str = "baseline") -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    transform, serve_bits, kv_dtype = VARIANTS[variant]
    fsdp = variant not in NO_FSDP_VARIANTS
    if shape.kind != "decode":
        serve_bits, kv_dtype = None, None
    cfg = transform(cfg) if (cfg.moe is not None or
                             not variant.startswith("moe")) else cfg
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": chips, "status": "ok", "variant": variant}

    # 1. full compile (scan) — the coherence proof + memory analysis
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh, unroll=False,
                         serve_bits=serve_bits, kv_dtype=kv_dtype, fsdp=fsdp)
    rec["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)
    rec["memory"] = RA.memory_dict(compiled)
    rec["cost_raw"] = RA.cost_dict(compiled)   # body-once; see roofline note
    rec["coll_raw"] = RA.collective_bytes(compiled.as_text())
    del compiled, lowered

    # 2. depth-reduced unrolled lowers -> affine fit -> full-depth roofline
    if not skip_reduced:
        knobs = depth_knobs(cfg)
        fit = RA.fit_depth(
            lambda r: measure_variant(cfg, shape, mesh, r,
                                      serve_bits=serve_bits,
                                      kv_dtype=kv_dtype, fsdp=fsdp),
            len(knobs))
        full = fit.at(knobs)
        coll = full.get("coll_total", 0.0)
        roof = RA.Roofline(flops_per_chip=full["flops"],
                           bytes_per_chip=full["bytes"],
                           coll_bytes_per_chip=coll)
        rec["fit"] = {"base": fit.base,
                      "bodies": fit.bodies, "knobs": knobs}
        rec["roofline"] = roof.as_dict()

        # MODEL_FLOPS ratio (useful-compute fraction)
        params_shapes = SP.abstract_params(cfg)
        n_active = T.active_param_count(params_shapes, cfg)
        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind != "decode" else 1)
        mf = RA.model_flops(n_active, tokens,
                            "train" if shape.kind == "train" else "serve")
        rec["model_flops"] = mf
        rec["n_active_params"] = n_active
        hlo_global = full["flops"] * chips
        rec["useful_flops_ratio"] = mf / hlo_global if hlo_global else 0.0
    return rec


def cells(mesh_names):
    for arch in ARCHS:
        for shape_name in SHAPES:
            for mesh_name in mesh_names:
                yield arch, shape_name, mesh_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-reduced", action="store_true",
                    help="full compile only (no roofline extrapolation)")
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    ap.add_argument("--out", default=str(ART))
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    mesh_names = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        todo = list(cells(mesh_names))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape, m) for m in mesh_names]

    failures = 0
    for arch, shape_name, mesh_name in todo:
        # roofline extrapolation only needed on the single-pod mesh
        skip_reduced = args.skip_reduced or (mesh_name == "multi")
        suffix = "" if args.variant == "baseline" else f"__{args.variant}"
        path = out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
        if path.exists() and not args.force:
            print(f"[skip-existing] {path.name}")
            continue
        t0 = time.time()
        try:
            rec = run_cell(arch, shape_name, mesh_name,
                           skip_reduced=skip_reduced, variant=args.variant)
        except Exception as e:  # record the failure, keep sweeping
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            failures += 1
        rec["wall_s"] = round(time.time() - t0, 2)
        path.write_text(json.dumps(rec, indent=2))
        status = rec["status"]
        extra = ""
        if status == "ok" and "roofline" in rec:
            r = rec["roofline"]
            extra = (f" dom={r['dominant']} t_step={r['t_step_s']:.4g}s "
                     f"useful={rec['useful_flops_ratio']:.2f}")
        print(f"[{status}] {arch} x {shape_name} x {mesh_name} "
              f"({rec['wall_s']}s){extra}", flush=True)
    print(f"done; failures={failures}")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
