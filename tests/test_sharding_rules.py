"""Sharding-rule unit tests — run against abstract params (no devices needed;
rules must resolve on ShapeDtypeStructs) with a symbolic 16x16 mesh built
from the single real CPU device via AbstractMesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.dist import sharding as SH
from repro.nn import transformer as T

MESH = SH.abstract_mesh((16, 16), ("data", "model"))
MESH3 = SH.abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _abstract(name):
    cfg = ARCHS[name]
    return cfg, jax.eval_shape(lambda k: T.init(k, cfg), jax.random.PRNGKey(0))


def _find(specs, params, substr):
    out = []
    for (p, spec), (_, leaf) in zip(
            jax.tree_util.tree_leaves_with_path(specs,
                                                is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_leaves_with_path(params)):
        ps = SH.path_str(p)
        if substr in ps:
            out.append((ps, spec, leaf.shape))
    return out


def test_dense_tp_and_fsdp_axes():
    cfg, params = _abstract("nemotron-4-340b")
    specs = SH.param_specs(params, MESH)
    wq = _find(specs, params, "wq/kernel")[0]
    # (L, d, H, hd): fsdp on d, heads on model
    assert wq[1] == P(None, "data", "model", None), wq
    wo = _find(specs, params, "mlp/wo/kernel")[0]
    assert wo[1] == P(None, "model", "data"), wo
    emb = _find(specs, params, "embed/table")[0]
    assert emb[1] == P("model", None), emb


def test_non_divisible_heads_fall_back_to_replication():
    cfg, params = _abstract("gemma2-2b")      # 8 q-heads on 16-way model axis
    specs = SH.param_specs(params, MESH)
    wq = _find(specs, params, "wq/kernel")[0]
    assert wq[1][2] is None, "8 heads must not shard on a 16-way axis"
    # ffn still TP
    wi = _find(specs, params, "wi_gate/kernel")[0]
    assert wi[1][-1] == "model"


def test_moe_expert_parallel():
    cfg, params = _abstract("deepseek-v2-236b")
    specs = SH.param_specs(params, MESH)
    e = _find(specs, params, "experts/wi_gate")[0]
    # (L, E, d, de): experts on model (160 % 16 == 0)
    assert e[1] == P(None, "model", "data", None), e
    r = _find(specs, params, "router/kernel")[0]
    assert r[1][-1] is None, "router output dim stays replicated"


def test_mamba_tp_on_inner_dim():
    cfg, params = _abstract("falcon-mamba-7b")
    specs = SH.param_specs(params, MESH)
    a = _find(specs, params, "A_log")[0]
    assert a[1] == P(None, "model", None), a
    o = _find(specs, params, "out_proj/kernel")[0]
    assert o[1] == P(None, "model", "data"), o


def test_norms_replicated():
    cfg, params = _abstract("qwen3-0.6b")
    specs = SH.param_specs(params, MESH)
    for ps, spec, shape in _find(specs, params, "norm"):
        assert spec == P(), (ps, spec)


def test_cache_specs_prefer_kv_then_seq():
    cfg = ARCHS["gemma-7b"]                  # kv=16 -> kv-sharded
    state = jax.eval_shape(
        lambda: T.init_decode_state(cfg, 128, 1024, jnp.bfloat16))
    specs = SH.cache_specs(state, MESH)
    ks = _find(specs, state, "/k")[0]
    # (repeats, B, S, KV, hd): batch on data, KV on model
    assert ks[1] == P(None, ("data",), None, "model", None), ks

    cfg2 = ARCHS["qwen3-0.6b"]               # kv=8 -> seq-sharded
    state2 = jax.eval_shape(
        lambda: T.init_decode_state(cfg2, 128, 1024, jnp.bfloat16))
    specs2 = SH.cache_specs(state2, MESH)
    ks2 = _find(specs2, state2, "/k")[0]
    assert ks2[1] == P(None, ("data",), "model", None, None), ks2


def test_cache_specs_batch_replicated_when_not_divisible():
    cfg = ARCHS["falcon-mamba-7b"]
    state = jax.eval_shape(
        lambda: T.init_decode_state(cfg, 1, 64, jnp.bfloat16))
    specs = SH.cache_specs(state, MESH, shard_batch=False)
    h = _find(specs, state, "/h")[0]
    assert h[1][1] is None                    # batch replicated
    assert "model" in h[1]                    # d_inner sharded


def test_pod_axis_in_batch():
    assert SH.batch_axes(MESH3) == ("pod", "data")
    spec = SH.batch_spec(MESH3, 2)
    assert spec == P(("pod", "data"), None)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_every_param_gets_valid_spec(name):
    """Every leaf resolves; every sharded dim is divisible by its axis."""
    cfg, params = _abstract(name)
    specs = SH.param_specs(params, MESH)
    flat_s = jax.tree_util.tree_leaves(specs,
                                       is_leaf=lambda x: isinstance(x, P))
    flat_p = jax.tree_util.tree_leaves(params)
    assert len(flat_s) == len(flat_p)
    sizes = dict(MESH.shape)
    for spec, leaf in zip(flat_s, flat_p):
        for d, ax in enumerate(tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([sizes[a] for a in axes]))
            assert leaf.shape[d] % total == 0, (spec, leaf.shape, d)
