"""Optional-hypothesis shim for the property-based test modules.

When `hypothesis` is installed (requirements-dev.txt) this re-exports the
real `given` / `settings` / `strategies`, so property tests run at full
strength. On a bare interpreter the shim degrades each @given test into a
single cleanly-skipped test (with an install hint) instead of killing
collection of the whole module — the plain unit tests in those modules
keep running either way.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False
    _REASON = ("hypothesis not installed — property-based tests skipped "
               "(pip install -r requirements-dev.txt to run them)")

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                pytest.skip(_REASON)
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Inert placeholder: strategy constructors only need to exist."""
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategy()
