"""Fault-tolerant island-model search runtime.

`islands` — N NSGA-II islands with independent RNG streams, periodic elite
migration, deadline-based straggler ejection (`dist.fault_tolerance`) and a
shared evaluation memo over the flock-merged on-disk `EvalCache`.
`runtime` — checkpoint/resume of the whole fleet via `ckpt.CheckpointManager`;
a resumed search is bit-identical to the uninterrupted one.
`faults` — deterministic fault-injection harness (island kills, evaluation
exceptions, simulated preemption, cache tearing) for the recovery tests.
"""
from repro.search.islands import (Island, IslandConfig, IslandFleet,
                                  IslandKilled)
from repro.search.runtime import (PreemptedError, SearchConfig, SearchResult,
                                  SearchRuntime)

__all__ = ["Island", "IslandConfig", "IslandFleet", "IslandKilled",
           "PreemptedError", "SearchConfig", "SearchResult", "SearchRuntime"]
