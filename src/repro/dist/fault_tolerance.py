"""Straggler / failure handling for the synchronous training loop.

Synchronous data parallelism moves at the pace of the slowest host. The
policy here is deadline-based ejection: hosts that miss the step deadline
are dropped from the step and their share of the global batch is
redistributed over the survivors, so throughput degrades gracefully
instead of stalling the whole pod behind one bad VM.
"""
from __future__ import annotations

from typing import Dict, List, Sequence


def deadline_barrier(arrival_times_s: Sequence[float],
                     deadline_s: float) -> List[bool]:
    """Which hosts made the barrier: True = arrived within the deadline and
    participates in this step, False = straggler, ejected for the step."""
    return [float(t) <= float(deadline_s) for t in arrival_times_s]


def redistribute_batch(global_batch: int, alive: Sequence[bool]
                       ) -> Dict[int, int]:
    """Deal `global_batch` examples over the alive hosts (dead hosts get 0).
    Shares differ by at most 1; the sum is exactly `global_batch`."""
    alive_ids = [i for i, ok in enumerate(alive) if ok]
    if not alive_ids:
        raise RuntimeError("no alive hosts to redistribute the batch onto")
    base, rem = divmod(int(global_batch), len(alive_ids))
    deal = {i: 0 for i in range(len(alive))}
    for j, h in enumerate(alive_ids):
        deal[h] = base + (1 if j < rem else 0)
    return deal


def should_checkpoint_now(step: int, *, every: int,
                          preemption_requested: bool) -> bool:
    """Checkpoint cadence + immediate flush on a preemption notice."""
    return preemption_requested or (every > 0 and step % every == 0)
