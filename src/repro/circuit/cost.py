"""Structural area/power/delay from the netlist itself.

`hw_model` prices a design from coefficient statistics (CSD digit counts,
operand counts) without ever building a circuit. This module prices the
*materialized* netlist by counting nodes and edges, using the same
FA-equivalent width conventions — so for every compiled model the two must
agree exactly: multiplier count = product-subnet roots, CSD-digit sum =
mult-tagged SHL wires, adder count = tree + bias ADDs, operand counts =
product edges into each neuron's tree. That agreement (tested per layer in
``tests/test_circuit.py``) turns the analytic cost model from an assumption
into an invariant of the compiler.

What the netlist adds beyond the analytic model is *delay*: the critical
path in adder stages (`ir.Netlist.depths`), which the coefficient
statistics cannot see — it depends on how deep the shift-add chains and
adder trees actually compose.

The pricing is also *approximation-aware* (`repro.approx`): a ``TRUNC``
node is free wiring, and an adder/comparator whose operands provably carry
k zeroed low bits (only an explicit TRUNC chain establishes this — never
structural trailing zeros, so exact netlists price exactly as before)
costs k fewer full-adder equivalents. Truncated-CSD multipliers get
cheaper automatically: fewer digits means fewer mult-tagged SHL wires.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

from repro.core import hw_model as HW
from repro.circuit import ir

# Printed EGT gate-stage delay. Calibrated so the dense 8-bit bespoke
# classifiers land at the few-Hz operating points reported for printed
# bespoke MLPs (MICRO'20 runs them at single-digit Hz): ~20-30 stages x
# ~5 ms -> ~100 ms/inference. Ripple within an adder is folded into the
# stage constant (same simplification as the area model's FA-equivalents).
DELAY_FA_MS = 5.0


@dataclasses.dataclass
class StructuralLayerCost:
    n_multipliers: int        # product-subnet roots
    csd_digits: int           # mult-tagged SHL wires (one per CSD digit)
    n_adders: int             # tree + bias ADD/SUB gates
    max_operands: int         # widest neuron fan-in (product edges)
    mult_fa: float
    adder_fa: float
    act_fa: float

    @property
    def total_fa(self) -> float:
        return self.mult_fa + self.adder_fa + self.act_fa


@dataclasses.dataclass
class StructuralCost:
    layers: List[StructuralLayerCost]
    argmax_fa: float
    critical_path_levels: int

    @property
    def total_fa(self) -> float:
        return sum(l.total_fa for l in self.layers) + self.argmax_fa

    @property
    def area_mm2(self) -> float:
        return self.total_fa * HW.AREA_FA_MM2

    @property
    def power_mw(self) -> float:
        return self.total_fa * HW.POWER_FA_MW

    @property
    def n_multipliers(self) -> int:
        return sum(l.n_multipliers for l in self.layers)

    @property
    def delay_ms(self) -> float:
        return self.critical_path_levels * DELAY_FA_MS

    @property
    def max_hz(self) -> float:
        return 1e3 / max(self.delay_ms, 1e-9)


def _trunc_levels(net: ir.Netlist) -> List[int]:
    """Guaranteed zeroed low bits per node, established ONLY by explicit
    TRUNC nodes (never by structural trailing zeros — a power-of-two
    product is still priced at full width, preserving exact agreement with
    `hw_model` on unapproximated netlists). TRUNC sets/extends the level;
    ADD/SUB keep the min of their operands (a sum of multiples of 2^k is a
    multiple of 2^k); NEG/RELU preserve it; everything else resets to 0."""
    tz = [0] * len(net.nodes)
    for n in net.nodes:
        if n.op == ir.Op.TRUNC:
            tz[n.id] = max(tz[n.args[0]], n.shift)
        elif n.op in (ir.Op.ADD, ir.Op.SUB):
            tz[n.id] = min(tz[a] for a in n.args)
        elif n.op in (ir.Op.NEG, ir.Op.RELU):
            tz[n.id] = tz[n.args[0]]
    return tz


def structural_cost(net: ir.Netlist) -> StructuralCost:
    """Price the netlist from its structure alone (node/edge counts +
    the analytic model's width conventions). Approximation-aware: TRUNC
    nodes are free, and gates downstream of a TRUNC chain are priced at
    their narrowed width (see `_trunc_levels`)."""
    L = net.n_layers
    n_mult = [0] * L
    csd = [0] * L
    adders = [0] * L
    adder_fa = [0.0] * L
    relus = [0] * L
    # operand count per (layer, neuron): product edges into the tree/bias
    operands: List[Dict[int, int]] = [dict() for _ in range(L)]
    # a tree operand is a product root, possibly seen through TRUNC wiring
    reaches_root = [False] * len(net.nodes)
    for n in net.nodes:
        reaches_root[n.id] = n.product_root or (
            n.op == ir.Op.TRUNC and reaches_root[n.args[0]])
    tz = _trunc_levels(net)

    for n in net.nodes:
        pw = net.in_bits + net.w_bits[n.layer] if 0 <= n.layer < L else 0
        if n.role == ir.ROLE_MULT:
            if n.product_root:
                n_mult[n.layer] += 1
            if n.op == ir.Op.SHL:
                csd[n.layer] += 1
        elif n.role in (ir.ROLE_TREE, ir.ROLE_BIAS):
            if n.op in (ir.Op.ADD, ir.Op.SUB):
                adders[n.layer] += 1
                disc = min(tz[a] for a in n.args)
                adder_fa[n.layer] += float(max(pw - disc, 1))
            k = n.unit[0]
            ops = operands[n.layer]
            ops[k] = ops.get(k, 0) + sum(
                1 for a in n.args if reaches_root[a])
        elif n.role == ir.ROLE_RELU:
            # no width discount here: a ReLU's operand is the bias add,
            # and the hardwired bias constant restores full width (its
            # trunc level is 0 by definition), so truncation upstream in
            # the tree can never narrow the comparator
            relus[n.layer] += 1

    layers = []
    for i in range(L):
        prod_width = net.in_bits + net.w_bits[i]
        max_ops = max(operands[i].values(), default=0)
        acc_w = prod_width + math.ceil(math.log2(max(max_ops, 2)))
        act_fa = relus[i] * HW.RELU_FA_EQ * acc_w
        layers.append(StructuralLayerCost(
            n_multipliers=n_mult[i],
            csd_digits=csd[i],
            n_adders=adders[i],
            max_operands=max_ops,
            mult_fa=float(csd[i] * prod_width) * HW.MULT_ROUTING_FACTOR,
            adder_fa=adder_fa[i],
            act_fa=act_fa))

    am = net.nodes[net.argmax_id] if net.argmax_id is not None else None
    n_logits = len(am.args) if am is not None else 0
    am_w = net.in_bits + net.w_bits[-1] + 4
    if am is not None and am.args:
        am_w = max(am_w - min(tz[a] for a in am.args), 1)
    argmax_fa = max(n_logits - 1, 0) * HW.ARGMAX_FA_EQ * am_w
    return StructuralCost(layers, argmax_fa, net.critical_path_levels())


def cross_validate(net: ir.Netlist, compiled) -> Dict:
    """Compare the structural pricing of ``net`` against `hw_model`'s
    analytic pricing of the same compiled model, layer by layer. Returns a
    report dict with ``ok`` plus every per-layer count pair — used by the
    test suite and the example's circuit summary."""
    sc = structural_cost(net)
    ac = HW.mlp_cost(compiled.q_layers, w_bits=compiled.w_bits,
                     in_bits=compiled.input_bits,
                     clusters=compiled.clusters)
    layers = []
    ok = True
    for s, a in zip(sc.layers, ac.layers):
        row = {
            "n_multipliers": (s.n_multipliers, a.n_multipliers),
            "mult_fa": (s.mult_fa, a.mult_fa),
            "adder_fa": (s.adder_fa, a.adder_fa),
            "act_fa": (s.act_fa, a.act_fa),
        }
        layers.append(row)
        ok &= all(abs(x - y) <= 1e-9 * max(abs(x), abs(y), 1.0)
                  for x, y in row.values())
    ok &= abs(sc.argmax_fa - ac.argmax_fa) <= 1e-9
    ok &= abs(sc.total_fa - ac.total_fa) <= 1e-6 * max(ac.total_fa, 1.0)
    return {"ok": bool(ok), "layers": layers,
            "argmax_fa": (sc.argmax_fa, ac.argmax_fa),
            "total_fa": (sc.total_fa, ac.total_fa),
            "structural": sc, "analytic": ac}


def describe(net: ir.Netlist, sc: StructuralCost = None) -> str:
    """Human-readable compiled-circuit summary (example / bench output)."""
    sc = sc or structural_cost(net)
    ops = net.op_counts()
    lines = [
        f"netlist: {len(net)} nodes "
        f"({', '.join(f'{k}={v}' for k, v in sorted(ops.items()))})",
        f"max wordlength: {net.max_width} bits",
        f"multipliers: {sc.n_multipliers}  "
        f"adders: {sum(l.n_adders for l in sc.layers)}  "
        f"csd digits: {sum(l.csd_digits for l in sc.layers)}",
        f"area: {sc.area_mm2 / 100:.2f} cm^2  power: {sc.power_mw:.2f} mW",
        f"critical path: {sc.critical_path_levels} adder stages "
        f"(~{sc.delay_ms:.0f} ms/inference, ~{sc.max_hz:.1f} Hz)",
    ]
    for i, l in enumerate(sc.layers):
        lines.append(
            f"  layer {i}: mult={l.n_multipliers} csd={l.csd_digits} "
            f"adders={l.n_adders} fan-in<= {l.max_operands} "
            f"fa={l.total_fa:.0f}")
    return "\n".join(lines)
