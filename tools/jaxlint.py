#!/usr/bin/env python
"""jaxlint — stdlib-only AST lint for the repo's JAX/int-domain hazards.

Three rule families (see DESIGN.md §5):

INT-DOMAIN PURITY (``int-domain``) — the exact-arithmetic core
  (`circuit/ir.py`, `approx/rewrite.py`, `approx/analyze.py`) proves error
  bounds with Python ints. Any numpy/jax import (module- or
  function-level) or a true-division operator (``/``) in those modules
  would smuggle float semantics into the proofs.

TRACER HAZARDS (``tracer-branch``, ``numpy-in-jit``) — inside a function
  decorated with ``@jax.jit`` / ``@functools.partial(jax.jit, ...)``,
  a Python ``if``/``while`` whose test reads a *non-static* parameter
  branches on a tracer (trace-time crash or silent constant-folding), and
  a ``np.*`` call materializes the tracer on host. Branching on static
  params or on shape-derived locals is idiomatic and is NOT flagged.

STATIC-ARGNAMES HYGIENE (``static-argnames``) — every name listed in
  ``static_argnames`` must exist in the decorated function's signature,
  and a parameter with a mutable-literal default (list/dict/set —
  unhashable) must not be declared static.

OBSERVABILITY BOUNDARY (``obs-in-jit``) — `repro.obs` spans/events/metrics
  are host-side: they take wall-clock timestamps and append to process
  state. Inside a jit-traced body they would run once at trace time and
  then never again (a span would "time" the trace, not the computation).
  Telemetry must wrap the *dispatch* of a jit'd function, never live
  inside it.

RECOMPILE HAZARDS (``jit-in-loop``) — ``jax.jit(...)`` constructed inside
  a loop body builds a fresh jit wrapper (and, on dispatch, a fresh
  trace+compile) every iteration; ``jax.jit(f)(x)`` constructed and
  invoked in one expression inside a function does the same on every
  call. Both defeat jax's dispatch cache — the executable observatory
  (`repro.obs.prof`) can only *report* the resulting recompile storm
  after the fact; this rule rejects the pattern statically. Hoist the
  construction to module scope, an attribute, or a cached factory.
  (A jit constructed once per call but dispatched many times in a loop —
  the entry-point idiom — is NOT flagged: whether the enclosing function
  is itself hot is not statically decidable; that case is exactly what
  the observatory's recompile accounting exists for.)

Usage::

    python tools/jaxlint.py src/          # exit 1 on findings
    python tools/jaxlint.py a.py b.py

Stdlib only — runs on a bare interpreter, usable as a CI gate before any
heavyweight dependency installs.
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path
from typing import List, NamedTuple, Optional, Sequence, Set

# modules held to exact-Python-int purity, relative to any scan root
INT_DOMAIN_MODULES = (
    "repro/circuit/ir.py",
    "repro/approx/rewrite.py",
    "repro/approx/analyze.py",
)

FORBIDDEN_IN_INT_DOMAIN = ("numpy", "jax")


class Finding(NamedTuple):
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


# ---------------------------------------------------------------------------
# decorator recognition
# ---------------------------------------------------------------------------

def _dotted(node: ast.AST) -> str:
    """'jax.jit' for Attribute/Name chains, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_jit_ref(node: ast.AST) -> bool:
    return _dotted(node) in ("jax.jit", "jit")


def _is_jit_construction(node: ast.AST) -> bool:
    """``jax.jit(...)`` or ``functools.partial(jax.jit, ...)`` call."""
    if not isinstance(node, ast.Call):
        return False
    if _is_jit_ref(node.func):
        return True
    return (_dotted(node.func) in ("functools.partial", "partial")
            and bool(node.args) and _is_jit_ref(node.args[0]))


def _static_argnames(call: ast.Call) -> Optional[Set[str]]:
    """The static_argnames literal of a jit call, or None if absent /
    not statically resolvable."""
    for kw in call.keywords:
        if kw.arg != "static_argnames":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, str):
            return {v.value}
        if isinstance(v, (ast.Tuple, ast.List)):
            names = set()
            for elt in v.elts:
                if not (isinstance(elt, ast.Constant)
                        and isinstance(elt.value, str)):
                    return None
                names.add(elt.value)
            return names
    return None


def _jit_decoration(fn: ast.FunctionDef) -> Optional[Set[str]]:
    """Static argnames if ``fn`` is jit-decorated (empty set when jit takes
    no static_argnames), else None."""
    for dec in fn.decorator_list:
        if _is_jit_ref(dec):
            return set()
        if isinstance(dec, ast.Call):
            # @jax.jit(static_argnames=...)
            if _is_jit_ref(dec.func):
                return _static_argnames(dec) or set()
            # @functools.partial(jax.jit, static_argnames=...)
            if (_dotted(dec.func) in ("functools.partial", "partial")
                    and dec.args and _is_jit_ref(dec.args[0])):
                return _static_argnames(dec) or set()
    return None


def _param_names(fn: ast.FunctionDef) -> List[str]:
    a = fn.args
    names = [p.arg for p in
             (*a.posonlyargs, *a.args, *a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _mutable_default_params(fn: ast.FunctionDef) -> Set[str]:
    """Parameters whose default is a list/dict/set literal (unhashable)."""
    a = fn.args
    out: Set[str] = set()
    pos = [*a.posonlyargs, *a.args]
    for p, d in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        if isinstance(d, (ast.List, ast.Dict, ast.Set)):
            out.add(p.arg)
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if isinstance(d, (ast.List, ast.Dict, ast.Set)):
            out.add(p.arg)
    return out


# ---------------------------------------------------------------------------
# per-file checks
# ---------------------------------------------------------------------------

def _numpy_aliases(tree: ast.Module) -> Set[str]:
    """Names the file binds to the numpy module (``np``, ``numpy``)."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for al in node.names:
                if al.name == "numpy" or al.name.startswith("numpy."):
                    out.add((al.asname or al.name).split(".")[0])
    return out


def _obs_aliases(tree: ast.Module) -> tuple:
    """(module aliases, function aliases) the file binds to `repro.obs`.
    Module aliases cover ``from repro.obs import trace as TR`` /
    ``import repro.obs``; function aliases cover
    ``from repro.obs import span`` / ``from repro.obs.trace import event``."""
    mods: Set[str] = set()
    funcs: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for al in node.names:
                if al.name == "repro.obs" or al.name.startswith("repro.obs."):
                    mods.add((al.asname or al.name).split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == "repro.obs" or mod.startswith("repro.obs."):
                for al in node.names:
                    bound = al.asname or al.name
                    # submodule import (trace/metrics/...) vs function import
                    if mod == "repro.obs" and al.name in (
                            "trace", "metrics", "ring", "report",
                            "prof", "xprof"):
                        mods.add(bound)
                    else:
                        funcs.add(bound)
            elif mod == "repro":
                for al in node.names:
                    if al.name == "obs":
                        mods.add(al.asname or al.name)
    return mods, funcs


def _check_int_domain(path: str, tree: ast.Module) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for al in node.names:
                root = al.name.split(".")[0]
                if root in FORBIDDEN_IN_INT_DOMAIN:
                    out.append(Finding(
                        path, node.lineno, "int-domain",
                        f"import of '{al.name}' in a pure-int module — "
                        "the error-bound proofs must not touch "
                        "float/array semantics"))
        elif isinstance(node, ast.ImportFrom):
            root = (node.module or "").split(".")[0]
            if root in FORBIDDEN_IN_INT_DOMAIN:
                out.append(Finding(
                    path, node.lineno, "int-domain",
                    f"import from '{node.module}' in a pure-int module"))
        elif isinstance(node, ast.BinOp) and isinstance(node.op, ast.Div):
            out.append(Finding(
                path, node.lineno, "int-domain",
                "true division ('/') in a pure-int module — use '//' or "
                "shifts; '/' yields float"))
    return out


def _check_jit_body(path: str, fn: ast.FunctionDef, static: Set[str],
                    np_aliases: Set[str],
                    obs_aliases: tuple = (frozenset(), frozenset()),
                    ) -> List[Finding]:
    out: List[Finding] = []
    tracer_params = set(_param_names(fn)) - static
    obs_mods, obs_funcs = obs_aliases

    # static_argnames hygiene
    missing = static - set(_param_names(fn))
    for name in sorted(missing):
        out.append(Finding(
            path, fn.lineno, "static-argnames",
            f"static_argnames entry '{name}' is not a parameter of "
            f"{fn.name}()"))
    for name in sorted(static & _mutable_default_params(fn)):
        out.append(Finding(
            path, fn.lineno, "static-argnames",
            f"static parameter '{name}' of {fn.name}() has an unhashable "
            "mutable-literal default"))

    for node in ast.walk(fn):
        if isinstance(node, (ast.If, ast.While)):
            hit = sorted({n.id for n in ast.walk(node.test)
                          if isinstance(n, ast.Name)
                          and n.id in tracer_params})
            if hit:
                kw = "if" if isinstance(node, ast.If) else "while"
                out.append(Finding(
                    path, node.lineno, "tracer-branch",
                    f"Python '{kw}' on traced parameter(s) "
                    f"{', '.join(hit)} inside jit'd {fn.name}() — use "
                    "jnp.where/lax.cond or declare them static"))
        elif isinstance(node, ast.Call):
            dotted = _dotted(node.func)
            root = dotted.split(".")[0]
            if root in np_aliases and "." in dotted:
                out.append(Finding(
                    path, node.lineno, "numpy-in-jit",
                    f"numpy call '{dotted}' inside jit'd {fn.name}() — "
                    "numpy materializes tracers on host; use jnp"))
            elif ((root in obs_mods and "." in dotted)
                  or dotted in obs_funcs
                  or dotted.startswith("repro.obs.")):
                out.append(Finding(
                    path, node.lineno, "obs-in-jit",
                    f"obs call '{dotted}' inside jit'd {fn.name}() — "
                    "spans/events/metrics are host-side and would fire at "
                    "trace time only; wrap the dispatch instead"))
    return out


def _check_jit_in_loop(path: str, tree: ast.Module) -> List[Finding]:
    """Flag per-iteration / per-call jit construction (see module doc)."""
    out: List[Finding] = []
    seen: Set[int] = set()

    # (a) construction lexically inside a For/While body: a fresh wrapper
    # (and compile, on dispatch) every iteration
    for loop in ast.walk(tree):
        if not isinstance(loop, (ast.For, ast.AsyncFor, ast.While)):
            continue
        for node in ast.walk(loop):
            if _is_jit_construction(node) and node.lineno not in seen:
                seen.add(node.lineno)
                out.append(Finding(
                    path, node.lineno, "jit-in-loop",
                    "jax.jit constructed inside a loop body — every "
                    "iteration builds (and on dispatch compiles) a fresh "
                    "executable; hoist the construction out of the loop"))

    # (b) construct-and-dispatch in one expression inside a function:
    # ``jax.jit(f)(x)`` can never hit the wrapper's dispatch cache
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for node in ast.walk(fn):
            if (isinstance(node, ast.Call)
                    and _is_jit_construction(node.func)
                    and node.lineno not in seen):
                seen.add(node.lineno)
                out.append(Finding(
                    path, node.lineno, "jit-in-loop",
                    f"jax.jit constructed and invoked in one expression "
                    f"inside {fn.name}() — every call retraces and "
                    "recompiles; bind the jitted callable once (module "
                    "scope, attribute, or cached factory)"))
    return out


def lint_file(path: Path, *, rel: Optional[str] = None) -> List[Finding]:
    """Lint one file. ``rel`` (posix, e.g. 'repro/circuit/ir.py') decides
    int-domain membership; defaults to the path itself."""
    src = path.read_text()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:
        return [Finding(str(path), e.lineno or 0, "syntax", str(e.msg))]

    out: List[Finding] = []
    rel = rel if rel is not None else path.as_posix()
    if any(rel.endswith(m) for m in INT_DOMAIN_MODULES):
        out.extend(_check_int_domain(str(path), tree))
    out.extend(_check_jit_in_loop(str(path), tree))

    np_aliases = _numpy_aliases(tree)
    obs_aliases = _obs_aliases(tree)
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            static = _jit_decoration(node)
            if static is not None:
                out.extend(_check_jit_body(str(path), node, static,
                                           np_aliases, obs_aliases))
    return out


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    out: List[Finding] = []
    for p in paths:
        root = Path(p)
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for f in files:
            rel = f.relative_to(root).as_posix() if root.is_dir() \
                else f.as_posix()
            out.extend(lint_file(f, rel=rel))
    return sorted(out, key=lambda f: (f.path, f.line))


def main(argv: Sequence[str]) -> int:
    args = [a for a in argv if not a.startswith("-")]
    if not args:
        print(__doc__)
        return 2
    findings = lint_paths(args)
    for f in findings:
        print(f)
    if findings:
        print(f"jaxlint: {len(findings)} finding(s)")
        return 1
    print(f"jaxlint: clean ({', '.join(args)})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
