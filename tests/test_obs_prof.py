"""Executable observatory (`repro.obs.prof` / `repro.obs.xprof`): off-path
inertness, dispatch/capture registry contracts, compile counting, profiled
results bit-identical to unprofiled ones, registry checkpoint/resume
dict-equality, and the report's executables/padding sections against a
committed profiled-run golden."""
import dataclasses
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.printed_mlp import PRINTED_MLPS
from repro.core import batch_eval as BE
from repro.core.compression_spec import ModelMin
from repro.core.ga import GAConfig
from repro.kernels.quant_matmul import quant_matmul
from repro.obs import prof as PF
from repro.obs import report
from repro.obs import trace as TR
from repro.obs import xprof
from repro.search import (IslandConfig, PreemptedError, SearchConfig,
                          SearchRuntime)
from repro.search.faults import FaultHarness, FaultPlan

DATA = Path(__file__).resolve().parent / "data"


def _tracing_off():
    """See tests/test_obs.py — CI runs with REPRO_TRACE=1; detach it."""
    import contextlib

    @contextlib.contextmanager
    def cm():
        prev, TR._tracer = TR._tracer, None
        try:
            yield
        finally:
            TR._tracer = prev
    return cm()


def _qm_args(seed=0):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(8, 16)), jnp.float32)
    w_q = jnp.asarray(r.integers(-7, 8, size=(16, 12)), jnp.int8)
    scales = jnp.asarray(r.uniform(0.1, 1.0, size=(12,)), jnp.float32)
    return x, w_q, scales


# ---------------------------------------------------------------------------
# off path: tracing off => the registry layer is never touched
# ---------------------------------------------------------------------------


def test_off_path_never_touches_registry(monkeypatch):
    """With REPRO_TRACE off, instrumented wrappers take their fast path:
    no dispatch record, no capture_executable, no registry mutation —
    provably zero observatory overhead."""
    calls = []
    real_dispatch = PF.dispatch
    monkeypatch.setattr(PF, "dispatch",
                        lambda *a, **k: calls.append("dispatch")
                        or real_dispatch(*a, **k))
    monkeypatch.setattr(xprof, "capture_executable",
                        lambda *a, **k: calls.append("capture") or {})
    PF.reset()
    with _tracing_off():
        y = quant_matmul(*_qm_args())
    jax.block_until_ready(y)
    assert calls == []
    assert PF.REGISTRY.executables == {}
    assert PF.REGISTRY.compiles == 0 and PF.REGISTRY.aot_compiles == 0


# ---------------------------------------------------------------------------
# on path: dispatch records, one-shot capture, trace events
# ---------------------------------------------------------------------------


def test_dispatch_records_and_captures_once(tmp_path):
    PF.reset()
    args = _qm_args()
    with TR.capture(tmp_path / "t.jsonl") as _:
        y1 = quant_matmul(*args)
        y2 = quant_matmul(*args)
    recs, damaged = TR.read_trace(tmp_path / "t.jsonl")
    assert damaged == 0
    assert np.array_equal(np.asarray(y1), np.asarray(y2))

    assert len(PF.REGISTRY.executables) == 1
    (key, rec), = PF.REGISTRY.executables.items()
    assert key.startswith("('quant_matmul'")
    assert rec["site"] == "kernels.quant_matmul"
    assert rec["dispatches"] == 2
    # the capture ran exactly once and landed cost/memory fields (or a
    # flagged capture error — never a crash)
    assert "signature" in rec
    if "error" not in rec:
        assert rec["flops"] >= 0
        assert rec["output_size_in_bytes"] > 0
    # the registry state is reconstructible from the trace stream
    ex_events = [r for r in recs if r.get("name") == "prof.executable"]
    assert len(ex_events) == 1 and ex_events[0]["attrs"]["key"] == key
    spans = [r for r in recs if r.get("name") == "kernels.quant_matmul"]
    assert len(spans) == 2
    assert [s["attrs"]["first"] for s in spans] == [True, False]


def test_snapshot_is_jsonable_sorted_and_drops_transients():
    import json
    PF.reset()
    rec = PF.REGISTRY.record("site.b", "kb")
    rec["_key"] = "kb"                      # in-flight transient
    PF.REGISTRY.record("site.a", "ka")
    PF.REGISTRY.on_compile(rec, 0.25, False)
    PF.REGISTRY.on_compile(None, 1.5, True)  # unattributed AOT compile
    snap = PF.snapshot()
    assert list(snap["executables"]) == ["ka", "kb"]
    assert "_key" not in snap["executables"]["kb"]
    assert snap["executables"]["kb"]["compiles"] == 1
    assert snap["totals"] == {"aot_compile_s": 1.5, "aot_compiles": 1,
                              "compile_s": 0.25, "compiles": 1}
    assert json.dumps(snap, sort_keys=True)  # checkpoint-serializable
    PF.reset()


def test_count_compiles_sees_fresh_backend_compile():
    """`xprof.count_compiles` needs no tracing — it is the bench-side
    recompile gate (netlist_bench's zero-compile acceptance)."""
    with _tracing_off():
        with xprof.count_compiles() as cc:
            jax.block_until_ready(
                jax.jit(lambda x: x * 3 + 1)(jnp.arange(11.0)))
        assert cc.compiles >= 1 and cc.compile_s > 0.0
        with xprof.count_compiles() as warm:
            jax.block_until_ready(
                jax.jit(lambda x: x * 3 + 1)(jnp.arange(11.0)))
    # a fresh jit of a fresh lambda compiles again; the point is the
    # counter observes the backend, not the python wrapper
    assert warm.compiles >= 0


# ---------------------------------------------------------------------------
# profiling does not perturb results (byte-equal on/off)
# ---------------------------------------------------------------------------


def _kernel_cases():
    from repro.kernels.block_sparse_matmul import block_sparse_matmul
    from repro.kernels.clustered_matmul import clustered_matmul
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ssm_scan import ssm_scan
    r = np.random.default_rng(7)
    f32 = lambda *s: jnp.asarray(r.normal(size=s), jnp.float32)  # noqa: E731
    # materialize every argument ONCE: a thunk that re-draws from the
    # shared rng per call would feed the traced lap different inputs than
    # the untraced one and fake a bit-equality failure
    cm = (f32(8, 16), jnp.asarray(r.integers(0, 4, (16, 12)), jnp.int32),
          f32(16, 4))
    bs = (f32(8, 16), f32(16, 8), jnp.ones((2, 1), jnp.int32))
    q, k, v = f32(1, 16, 2, 8), f32(1, 16, 2, 8), f32(1, 16, 2, 8)
    ssm = (f32(1, 8, 4), jnp.abs(f32(1, 8, 4)) + 0.1, f32(1, 8, 2),
           f32(1, 8, 2), -jnp.abs(f32(4, 2)), f32(4))
    return [
        ("kernels.quant_matmul", lambda: quant_matmul(*_qm_args(1))),
        ("kernels.clustered_matmul", lambda: clustered_matmul(
            *cm, block_m=8, block_n=8, block_k=8)),
        ("kernels.block_sparse_matmul", lambda: block_sparse_matmul(
            *bs, block_m=8, block_n=8, block_k=8)),
        ("kernels.flash_attention", lambda: flash_attention(
            q, k, v, causal=True, block_q=8, block_k=8)),
        ("kernels.ssm_scan", lambda: ssm_scan(*ssm, block_t=8)),
    ]


@pytest.mark.parametrize("site,call", _kernel_cases(),
                         ids=lambda c: c if isinstance(c, str) else "")
def test_every_kernel_wrapper_profiles_and_matches(site, call, tmp_path):
    """Each instrumented kernel wrapper: traced dispatch returns the same
    bytes as the fast path, registers exactly one executable for the key,
    and lands a first-dispatch capture (lower thunk args must match the
    real call — a drifted thunk shows up here as a capture error)."""
    with _tracing_off():
        base = np.asarray(call())
    PF.reset()
    with TR.capture(tmp_path / "t.jsonl"):
        traced = np.asarray(call())
    assert np.array_equal(base, traced)
    recs = [r for r in PF.REGISTRY.executables.values()
            if r["site"] == site]
    assert len(recs) == 1 and recs[0]["dispatches"] == 1
    assert "signature" in recs[0] and "error" not in recs[0]


def test_profiled_population_eval_bit_identical(tmp_path):
    """The acceptance contract: running the full packed evaluation stack
    (QAT finetune + netlist-exact scoring) with profiling on returns
    byte-identical results to the unprofiled run."""
    cfg = PRINTED_MLPS["seeds"]
    n_layers = len(cfg.layer_dims) - 1
    specs = [ModelMin.uniform(n_layers, bits=b, sparsity=s,
                              input_bits=cfg.input_bits)
             for b, s in ((4, 0.0), (3, 0.2), (5, 0.4))]
    with _tracing_off():
        base = BE.evaluate_population(cfg, specs, epochs=2, netlist=True)
    PF.reset()
    with TR.capture(tmp_path / "t.jsonl"):
        prof = BE.evaluate_population(cfg, specs, epochs=2, netlist=True)
    assert [dataclasses.asdict(r) for r in base] == \
        [dataclasses.asdict(r) for r in prof]
    # and the run actually exercised the observatory
    sites = {r["site"] for r in PF.REGISTRY.executables.values()}
    assert "eval.finetune" in sites
    assert any(s.startswith("kernels.netlist_sim") for s in sites)


# ---------------------------------------------------------------------------
# registry rides checkpoints: resume restores dict-equal
# ---------------------------------------------------------------------------


def _synthetic(spec):
    bits = sum(l.bits for l in spec.layers)
    return (bits / 16.0, sum(l.sparsity for l in spec.layers))


def _cfg():
    return SearchConfig(
        n_layers=2, rounds=4,
        ga=GAConfig(population=6, seed=3),
        islands=IslandConfig(n_islands=2, migration_every=2, migrants=1))


def test_checkpoint_resume_registry_dict_equal(tmp_path):
    PF.reset()
    rec = PF.REGISTRY.record("kernels.netlist_sim.levels", "('k', 1, 2)")
    rec["dispatches"] = 7
    rec["flops"] = 1234.0
    PF.REGISTRY.on_compile(rec, 0.125, False)
    saved = PF.snapshot()

    rt = SearchRuntime(_cfg(), evaluate=_synthetic, ckpt_root=tmp_path,
                       harness=FaultHarness(FaultPlan(preempt_at=1)))
    with pytest.raises(PreemptedError):
        rt.run()
    PF.reset()                               # simulate the fresh process
    assert PF.snapshot()["executables"] == {}
    SearchRuntime.resume(_cfg(), tmp_path, evaluate=_synthetic)
    assert PF.snapshot() == saved
    # pre-observatory checkpoints restore to empty, not a crash
    PF.restore(None)
    assert PF.snapshot()["executables"] == {}


# ---------------------------------------------------------------------------
# report: executables / padding / recompile sections
# ---------------------------------------------------------------------------


def _profiled_records():
    recs, damaged = TR.read_trace(DATA / "obs_trace_profiled.jsonl")
    assert damaged == 0
    return recs


def test_report_profiled_golden():
    """A recorded profiled run (2-island GA over the real packed netlist
    evaluator, REPRO_TRACE on) renders byte-identically to its golden —
    executables table, padding-waste table, recompile timeline and all."""
    txt = report.render(_profiled_records(), 0, "obs_trace_profiled.jsonl")
    golden = (DATA / "obs_report_profiled.txt").read_text()
    assert txt == golden


def test_report_profiled_sections_populated(tmp_path):
    recs = _profiled_records()
    ex = report.executables(recs)
    assert ex, "profiled fixture must contain executables"
    sites = {e["site"] for e in ex}
    assert "eval.finetune" in sites
    assert any(s.startswith("kernels.netlist_sim") for s in sites)
    for e in ex:
        assert e["dispatches"] >= 1 or e["compiles"] >= 1
    pad = report.padding_table(recs)
    assert pad and all(0.0 <= p["waste_pct"] <= 100.0 for p in pad)
    # the fixture's run compiled something: the timeline is non-empty and
    # every bucket count is non-negative
    tl = report.recompile_timeline(recs)
    assert tl and all(t["compiles"] >= 0 for t in tl)
    # CSV surface includes the two new files
    prefix = tmp_path / "run"
    report.write_csvs(recs, prefix)
    for section in ("executables", "padding"):
        f = Path(f"{prefix}.{section}.csv")
        assert f.exists() and len(f.read_text().splitlines()) > 1
