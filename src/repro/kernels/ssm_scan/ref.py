"""Pure-jnp oracle: the model's own selective scan (repro.nn.ssm)."""
from repro.nn.ssm import _selective_scan


def ssm_scan_ref(u, dt, B_, C_, A, D):
    y, _ = _selective_scan(u, dt, B_, C_, A, D)
    return y.astype(u.dtype)
