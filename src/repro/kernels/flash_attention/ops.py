"""jit'd wrapper: GQA head folding + padding + interpret fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.obs import prof as PF
from repro.obs import trace as TR


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def _flash_attention_jit(q, k, v, *, causal, window, softcap,
                         block_q, block_k, interpret):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    # fold batch+heads; broadcast kv over the group
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    kf = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (B, KV, G, S, hd)).reshape(B * H, S, hd)
    vf = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (B, KV, G, S, hd)).reshape(B * H, S, hd)
    bq = min(block_q, T) if T % min(block_q, T) == 0 else block_q
    bk = min(block_k, S) if S % min(block_k, S) == 0 else block_k
    padT = (-T) % bq
    padS = (-S) % bk
    if padT:
        qf = jnp.pad(qf, ((0, 0), (0, padT), (0, 0)))
    if padS:
        kf = jnp.pad(kf, ((0, 0), (0, padS), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, padS), (0, 0)))
        # padded keys sit at positions >= S: causal masking hides them for
        # q_pos < S; guard the non-causal case via window-free mask in kernel
    o = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                               softcap=softcap, block_q=bq, block_k=bk,
                               interpret=interpret)
    o = o[:, :T].reshape(B, H, T, hd).transpose(0, 2, 1, 3)
    return o


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=128, block_k=128, interpret: bool | None = None):
    """q: (B, T, H, hd); k/v: (B, S, KV, hd) with H % KV == 0.
    Returns (B, T, H, hd)."""
    if not TR.active():
        return _flash_attention_jit(q, k, v, causal=causal, window=window,
                                    softcap=softcap, block_q=block_q,
                                    block_k=block_k, interpret=interpret)
    key = ("flash_attention", q.shape, k.shape, causal, window, softcap,
           block_q, block_k)
    with PF.dispatch("kernels.flash_attention", key,
                     lower=lambda: _flash_attention_jit.lower(
                         q, k, v, causal=causal, window=window,
                         softcap=softcap, block_q=block_q, block_k=block_k,
                         interpret=interpret),
                     b=q.shape[0], t=q.shape[1], h=q.shape[2],
                     s=k.shape[1]):
        o = _flash_attention_jit(q, k, v, causal=causal, window=window,
                                 softcap=softcap, block_q=block_q,
                                 block_k=block_k, interpret=interpret)
        jax.block_until_ready(o)
    return o


__all__ = ["flash_attention", "flash_attention_ref"]
