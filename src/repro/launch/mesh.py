"""Production mesh factory.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state. The dry-run process (launch/dryrun.py) forces 512 host
placeholder devices *before* any jax import; ordinary processes see one CPU.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    """jax.make_mesh across versions: 0.4.x has no axis_types kwarg; newer
    versions default to Auto axes, which is what every caller here wants."""
    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16 data x 16 model). Multi-pod: 2 x 256 with a
    leading `pod` axis that composes with `data` for batch parallelism (the
    gradient all-reduce is the only cross-pod collective in steady state)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh for unit tests on the real device set."""
    return _mesh(shape, axes)
