"""Circuit compiler tests: netlist IR invariants, bit-exact equivalence of
the simulated netlist with the QAT integer forward, and exact agreement of
the structural cost with the analytic `hw_model` pricing."""
import numpy as np
import pytest

from repro import circuit
from repro.circuit import ir
from repro.configs.printed_mlp import PRINTED_MLPS
from repro.core import hw_model as HW
from repro.core import minimize as MZ
from repro.core.compression_spec import ModelMin

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def synth_compiled(dims, bits, *, in_bits=8, sparsity=0.0, clusters=None,
                   seed=0) -> MZ.CompiledMLP:
    """Fabricate a CompiledMLP directly (random integer weights on the
    quantization grid, consistent cluster structure) — exercises the
    compiler/simulator/cost over arbitrary spec combinations without
    training."""
    r = np.random.default_rng(seed)
    q_layers, scales, biases, cls, w_bits = [], [], [], [], []
    for d_in, d_out in zip(dims[:-1], dims[1:]):
        qmax = 2 ** (bits - 1) - 1
        if clusters:
            cb = r.integers(-qmax, qmax + 1, (d_in, clusters)).astype(
                np.int64)
            idx = r.integers(0, clusters, (d_in, d_out))
            q = np.take_along_axis(cb, idx, axis=1)
            q = q * (r.random((d_in, d_out)) >= sparsity)
            cls.append((idx, cb))
        else:
            q = r.integers(-qmax, qmax + 1, (d_in, d_out)).astype(np.int64)
            q[r.random((d_in, d_out)) < sparsity] = 0
            cls.append(None)
        q_layers.append(q)
        scales.append(float(r.uniform(0.002, 0.02)))
        biases.append(r.normal(0, 0.3, d_out).astype(np.float32))
        w_bits.append(bits)
    return MZ.CompiledMLP(q_layers, scales, biases, cls, w_bits, in_bits)


def assert_bit_exact(net, c, x):
    xq = MZ.quantize_inputs(c, x)
    ref_pres, ref_argmax = MZ.integer_forward(c, xq)
    out = circuit.simulate(net, xq)
    for i, (got, ref) in enumerate(zip(out["pre"], ref_pres)):
        np.testing.assert_array_equal(got, ref,
                                      err_msg=f"layer {i} pre-activations")
    np.testing.assert_array_equal(out["argmax"], ref_argmax)


def assert_cost_matches(net, c):
    cv = circuit.cross_validate(net, c)
    assert cv["ok"], cv["layers"]
    sc, ac = cv["structural"], cv["analytic"]
    for s, a in zip(sc.layers, ac.layers):
        assert s.n_multipliers == a.n_multipliers
        assert s.mult_fa == a.mult_fa
        assert s.adder_fa == a.adder_fa
        assert s.act_fa == a.act_fa
    assert sc.argmax_fa == ac.argmax_fa
    assert sc.n_multipliers == ac.n_multipliers
    assert sc.area_mm2 == pytest.approx(ac.area_mm2, rel=1e-12)
    assert sc.power_mw == pytest.approx(ac.power_mw, rel=1e-12)


# ---------------------------------------------------------------------------
# IR
# ---------------------------------------------------------------------------


def test_ir_widths_and_interval_arithmetic():
    net = ir.Netlist(in_bits=8, w_bits=[8])
    x = net.input(0)                       # [0, 255] -> 9 bits signed
    assert net.nodes[x].width == 9
    s = net.shl(x, 3)                      # [0, 2040]
    assert (net.nodes[s].lo, net.nodes[s].hi) == (0, 2040)
    n = net.neg(s)                         # [-2040, 0]
    assert net.nodes[n].width == 12
    d = net.sub(x, s)                      # [0-2040, 255-0]
    assert (net.nodes[d].lo, net.nodes[d].hi) == (-2040, 255)
    r = net.relu(d)
    assert (net.nodes[r].lo, net.nodes[r].hi) == (0, 255)


def test_ir_const_dedup_and_topo_order():
    net = ir.Netlist(in_bits=8, w_bits=[8])
    a = net.const(42)
    b = net.const(42)
    assert a == b
    assert net.const(-42) != a
    x = net.input(0)
    y = net.add(x, a)
    assert net.nodes[y].args == (x, a)
    levels = net.levels()
    assert x in levels[0] and y in levels[1]


def test_ir_depths_model():
    net = ir.Netlist(in_bits=8, w_bits=[8])
    x = net.input(0)
    s = net.shl(x, 2)                      # wire: +0
    a = net.add(s, net.shl(x, 0))          # +1
    r = net.relu(a)                        # +1
    depths = net.depths()
    assert depths[s] == 0 and depths[a] == 1 and depths[r] == 2


def test_csd_digits_reconstruct_and_count():
    for c in list(range(-300, 300)) + [2 ** 40 - 3, -(2 ** 40 - 3)]:
        digits = HW.csd_digits(c)
        assert sum(s << p for p, s in digits) == c
        assert len(digits) == HW.csd_nonzero_digits(c)
        # canonical: no two adjacent non-zero digits
        pos = sorted(p for p, _ in digits)
        assert all(b - a >= 2 for a, b in zip(pos, pos[1:]))


# ---------------------------------------------------------------------------
# bit-exact simulation vs the QAT integer forward (randomized spec sweep)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dims,bits,sparsity,clusters", [
    ((7, 8, 3), 8, 0.0, None),             # dense 8-bit baseline shape
    ((7, 8, 3), 2, 0.0, None),             # extreme quantization
    ((11, 10, 7), 6, 0.5, None),           # pruned
    ((11, 10, 7), 4, 0.0, 4),              # clustered
    ((16, 20, 10), 8, 0.3, 8),             # pruned + clustered
    ((5, 6, 6, 4), 7, 0.2, 3),             # 3 layers, everything on
])
def test_netlist_bit_exact_synthetic(dims, bits, sparsity, clusters):
    c = synth_compiled(dims, bits, sparsity=sparsity, clusters=clusters,
                       seed=hash((dims, bits)) % 2 ** 31)
    net = circuit.compile_netlist(c)
    x = RNG.random((17, dims[0])).astype(np.float32)
    assert_bit_exact(net, c, x)
    assert_cost_matches(net, c)


def test_netlist_bit_exact_wide_words_int64_path():
    """A deep stack pushes accumulator words past 31 bits: the simulator
    must switch to exact int64 and still match the reference."""
    c = synth_compiled((11, 12, 12, 7), 8, seed=3)
    net = circuit.compile_netlist(c)
    assert net.max_width > 31           # the point of this test
    assert_bit_exact(net, c, RNG.random((9, 11)).astype(np.float32))


def test_fully_pruned_neuron_keeps_bias_add():
    """A neuron whose whole input column is pruned still prints its bias
    accumulator — both models charge exactly one adder for it."""
    c = synth_compiled((6, 5, 3), 8, seed=7)
    c.q_layers[0][:, 2] = 0             # kill neuron 2 of the hidden layer
    net = circuit.compile_netlist(c)
    assert_bit_exact(net, c, RNG.random((11, 6)).astype(np.float32))
    assert_cost_matches(net, c)


def test_power_of_two_and_unit_coefficients_are_wires():
    """|coeff| a power of two lowers to a single SHL (plus NEG when
    negative): zero ADD/SUB gates inside the multiplier."""
    c = synth_compiled((3, 2), 8, seed=1)
    c.q_layers[0][:] = np.array([[1, -1], [64, -64], [2, 16]])
    net = circuit.compile_netlist(c)
    mult_adders = sum(1 for n in net.nodes
                      if n.role == ir.ROLE_MULT
                      and n.op in (ir.Op.ADD, ir.Op.SUB))
    assert mult_adders == 0
    assert_bit_exact(net, c, RNG.random((8, 3)).astype(np.float32))
    assert_cost_matches(net, c)


def test_all_negative_csd_recoding():
    """-5 recodes to (-4, -1): no positive digit, the chain needs its NEG."""
    c = synth_compiled((2, 1), 8, seed=1)
    c.q_layers[0][:] = np.array([[-5], [-3]])
    net = circuit.compile_netlist(c)
    assert_bit_exact(net, c, RNG.random((8, 2)).astype(np.float32))
    assert_cost_matches(net, c)


def test_single_sample_run():
    c = synth_compiled((7, 8, 3), 8)
    net = circuit.compile_netlist(c)
    xq = MZ.quantize_inputs(c, RNG.random((1, 7)).astype(np.float32))
    out = circuit.simulate(net, xq[0])      # 1-D input path
    ref_pres, ref_argmax = MZ.integer_forward(c, xq)
    np.testing.assert_array_equal(out["pre"][-1], ref_pres[-1][0])
    assert out["argmax"] == ref_argmax[0]


def test_cluster_sharing_collapses_products():
    """With per-input clustering the number of product subnets equals the
    analytic used-cluster count, not the active-weight count."""
    c = synth_compiled((8, 32), 8, clusters=3, seed=2)
    net = circuit.compile_netlist(c)
    roots = sum(1 for n in net.nodes if n.product_root)
    active = int((c.q_layers[0] != 0).sum())
    assert roots <= 8 * 3 < active
    assert_cost_matches(net, c)
    assert_bit_exact(net, c, RNG.random((6, 8)).astype(np.float32))


# ---------------------------------------------------------------------------
# every seed-dataset MLP through the real QAT-compile path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(PRINTED_MLPS))
@pytest.mark.parametrize("spec_kw", [
    dict(bits=8),                                      # MICRO'20 baseline
    dict(bits=4, sparsity=0.4, clusters=8),            # minimized point
])
def test_netlist_matches_qat_forward_on_dataset(name, spec_kw):
    cfg = PRINTED_MLPS[name]
    n_layers = len(cfg.layer_dims) - 1
    spec = ModelMin.uniform(n_layers, input_bits=cfg.input_bits, **spec_kw)
    params0, (_, _, xte, yte) = MZ.pretrain(cfg)
    masks = MZ.make_masks(params0, spec)
    compiled = MZ.compile_bespoke(params0, spec, masks)
    net = circuit.compile_netlist(compiled)
    assert_bit_exact(net, compiled, xte)
    assert_cost_matches(net, compiled)
    # the integer circuit only adds bias-constant rounding on top of the
    # float emulation: test-set predictions stay essentially identical
    acc_net = circuit.netlist_accuracy(net, compiled, xte, yte)
    acc_float = MZ.compiled_accuracy(compiled, xte, yte)
    assert abs(acc_net - acc_float) <= 0.02


def test_evaluate_spec_reports_netlist_delay():
    cfg = PRINTED_MLPS["seeds"]
    n_layers = len(cfg.layer_dims) - 1
    r = MZ.evaluate_spec(cfg, ModelMin.uniform(n_layers, bits=6), epochs=10)
    assert r.delay_levels is not None and r.delay_levels > 0


def test_population_netlist_mode_prices_identically():
    """The netlist-exact objective (the default) swaps only the accuracy
    for the bit-exact simulation vs the analytic opt-out (netlist=False);
    area/power/multipliers/delay are unchanged (the structural cost is the
    analytic cost — that's the cross-validation invariant)."""
    from repro.core import batch_eval as BE
    cfg = PRINTED_MLPS["seeds"]
    n_layers = len(cfg.layer_dims) - 1
    specs = [ModelMin.uniform(n_layers, bits=8),
             ModelMin.uniform(n_layers, bits=3, sparsity=0.3, clusters=4)]
    ra = BE.evaluate_population(cfg, specs, epochs=10, netlist=False)
    rn = BE.evaluate_population(cfg, specs, epochs=10, netlist=True)
    for a, b in zip(ra, rn):
        assert a.area_mm2 == b.area_mm2
        assert a.power_mw == b.power_mw
        assert a.n_multipliers == b.n_multipliers
        assert a.delay_levels == b.delay_levels
        assert abs(a.accuracy - b.accuracy) <= 0.05


def test_overflow_guard():
    """A degenerate scale chain that would exceed the 62-bit exact budget
    must be rejected at compile time, not silently wrapped at runtime."""
    c = synth_compiled((7, 8, 3), 8)
    c.scales[1] = 1e-16                 # blows up the layer-2 bias grid
    with pytest.raises(OverflowError):
        circuit.compile_netlist(c)
