"""Batched serving demo: greedy decode over the continuous-batching engine
for a dense, a hybrid (RG-LRU) and an SSM architecture (reduced configs).

Run:  PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax

from repro.configs import ARCHS
from repro.nn import transformer as T
from repro.serve.engine import Request, ServeEngine

for arch in ("qwen3-0.6b", "recurrentgemma-9b", "falcon-mamba-7b"):
    cfg = ARCHS[arch].reduced()
    params = T.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, batch=4, max_len=64)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new_tokens=8)
            for i in range(6)]
    t0 = time.time()
    eng.run(reqs)
    dt = time.time() - t0
    print(f"{arch:24s} {eng.stats.tokens_generated} tokens in {dt:5.1f}s "
          f"({eng.stats.tokens_generated/dt:6.1f} tok/s, reduced-CPU) "
          f"sample={reqs[0].output}")
