"""Island-model fleet semantics: stepped-GA equivalence, migration,
deterministic straggler ejection, offspring redistribution."""
import numpy as np

from repro.core import ga as GA
from repro.core.ga import GAConfig, run_nsga2
from repro.search.islands import IslandConfig, IslandFleet


def _evaluate(spec):
    bits = sum(l.bits for l in spec.layers)
    sp = sum(l.sparsity for l in spec.layers)
    return (bits / 16.0, sp)


# ---------------------------------------------------------------------------
# stepped GA API (the refactor the fleet is built on)
# ---------------------------------------------------------------------------


def test_stepped_ga_matches_run_nsga2():
    """init_ga_state + ga_generation consume the exact RNG stream of the
    monolithic loop: identical populations, history and evaluations."""
    cfg = GAConfig(population=8, generations=4, seed=11)
    ref = run_nsga2(2, _evaluate, cfg)

    memo = {}

    def fit_all(specs):
        for s in specs:
            k = s.to_json()
            if k not in memo:
                memo[k] = tuple(map(float, _evaluate(s)))
        return np.array([memo[s.to_json()] for s in specs])

    state = GA.init_ga_state(2, cfg)
    for _ in range(cfg.generations):
        state = GA.ga_generation(state, cfg, fit_all)

    assert [s.to_json() for s in state.population] == \
        [s.to_json() for s in ref.population]
    assert state.history == ref.history
    assert memo == ref.evaluations


def test_ga_generation_is_pure():
    cfg = GAConfig(population=6, generations=1, seed=5)
    state = GA.init_ga_state(2, cfg)
    pop0 = [s.to_json() for s in state.population]
    rng0 = state.rng_state
    hist0 = list(state.history)

    def fit_all(specs):
        return np.array([_evaluate(s) for s in specs])

    new = GA.ga_generation(state, cfg, fit_all)
    # the input state is untouched — exception rollback is "keep the old
    # state", which only works if nothing mutates it
    assert [s.to_json() for s in state.population] == pop0
    assert state.rng_state == rng0
    assert state.history == hist0
    assert new.generation == 1
    assert len(new.history) == 1


def test_run_nsga2_on_generation_callback():
    seen = []
    run_nsga2(2, _evaluate, GAConfig(population=6, generations=3, seed=2),
              on_generation=lambda st: seen.append(st.generation))
    assert seen == [1, 2, 3]


# ---------------------------------------------------------------------------
# fleet
# ---------------------------------------------------------------------------


def test_single_island_fleet_matches_run_nsga2():
    """A 1-island fleet with migration off is exactly run_nsga2."""
    ga_cfg = GAConfig(population=8, generations=3, seed=7)
    ref = run_nsga2(2, _evaluate, ga_cfg)
    fleet = IslandFleet(2, ga_cfg, IslandConfig(n_islands=1,
                                                migration_every=0),
                        evaluate=_evaluate)
    for _ in range(ga_cfg.generations):
        fleet.run_round()
    assert [s.to_json() for s in fleet.islands[0].state.population] == \
        [s.to_json() for s in ref.population]
    assert fleet.evaluations == ref.evaluations


def test_fleet_deterministic_and_islands_independent():
    ga_cfg = GAConfig(population=6, seed=3)
    icfg = IslandConfig(n_islands=3, migration_every=0)

    def run():
        fleet = IslandFleet(2, ga_cfg, icfg, evaluate=_evaluate)
        for _ in range(3):
            fleet.run_round()
        return fleet

    f1, f2 = run(), run()
    pops1 = [[s.to_json() for s in isl.state.population]
             for isl in f1.islands]
    pops2 = [[s.to_json() for s in isl.state.population]
             for isl in f2.islands]
    assert pops1 == pops2
    assert f1.evaluations == f2.evaluations
    # per-island seeds differ -> initial populations differ island-to-island
    inits = [tuple(s.to_json() for s in
                   GA.init_ga_state(2, isl.cfg).population)
             for isl in f1.islands]
    assert len(set(inits)) == len(inits)


def test_migration_ring_copies_elites():
    ga_cfg = GAConfig(population=6, seed=1)
    fleet = IslandFleet(2, ga_cfg,
                        IslandConfig(n_islands=3, migration_every=1,
                                     migrants=2),
                        evaluate=_evaluate)
    # run one round with migration disabled so we can snapshot the
    # pre-migration populations the exchange operates on
    fleet.icfg = IslandConfig(n_islands=3, migration_every=0, migrants=2)
    fleet.run_round()
    pre = [list(isl.state.population) for isl in fleet.islands]
    elites = []
    for pop in pre:
        ranked = GA.rank_population(fleet._fit_specs(pop))
        elites.append([pop[j].to_json() for j in ranked[:2]])
    fleet.icfg = IslandConfig(n_islands=3, migration_every=1, migrants=2)
    fleet._migrate()
    assert any(e["event"] == "migration" for e in fleet.events)
    for pos in range(3):
        dst = fleet.islands[(pos + 1) % 3]
        dst_json = [s.to_json() for s in dst.state.population]
        # the sender's pre-migration elites now live on the ring neighbour
        for e in elites[pos]:
            assert e in dst_json
        assert len(dst_json) == ga_cfg.population


def test_straggler_ejected_for_round_and_budget_redistributed():
    ga_cfg = GAConfig(population=6, seed=4)
    icfg = IslandConfig(n_islands=3, migration_every=0, deadline_s=1.0)
    slow = {(1, 2): 99.0}             # island 2 straggles in round 1

    fleet = IslandFleet(2, ga_cfg, icfg, evaluate=_evaluate,
                        timer=lambda i, r: slow.get((r, i), 0.0))
    for _ in range(3):
        fleet.run_round()
    gens = [isl.state.generation for isl in fleet.islands]
    assert gens == [3, 3, 2]          # island 2 lost exactly one round
    assert fleet.islands[2].ejections == 1
    ev = [e for e in fleet.events if e["event"] == "straggler_ejected"]
    assert ev == [{"round": 1, "island": 2, "event": "straggler_ejected",
                   "arrival_s": 99.0}]
    # ejection is graceful: everyone still sized, fleet still deterministic
    assert all(len(isl.state.population) == ga_cfg.population
               for isl in fleet.islands)


def test_all_straggle_waives_deadline_instead_of_deadlocking():
    ga_cfg = GAConfig(population=6, seed=4)
    icfg = IslandConfig(n_islands=2, migration_every=0, deadline_s=1.0)
    fleet = IslandFleet(2, ga_cfg, icfg, evaluate=_evaluate,
                        timer=lambda i, r: 50.0)
    fleet.run_round()
    assert [isl.state.generation for isl in fleet.islands] == [1, 1]
    assert any(e["event"] == "all_straggle_waived" for e in fleet.events)


def test_redistribution_grows_survivor_offspring():
    """With island 1 straggling, island 0 breeds its share: the round's
    child count is population + redistributed budget (observable through
    the number of distinct evaluation requests)."""
    calls = []

    def batch_evaluate(specs):
        calls.append(len(specs))
        return [_evaluate(s) for s in specs]

    ga_cfg = GAConfig(population=6, seed=9)
    icfg = IslandConfig(n_islands=2, migration_every=0, deadline_s=1.0)
    fleet = IslandFleet(2, ga_cfg, icfg, batch_evaluate=batch_evaluate,
                        timer=lambda i, r: 99.0 if (i, r) == (1, 0) else 0.0)
    fleet.run_round()
    # island 0's union this round was population parents + 12 children
    # (its 6 + island 1's dealt 6); dedup may shrink the eval calls but
    # the selection pool is the full 18
    assert fleet.islands[0].state.generation == 1
    assert fleet.islands[1].state.generation == 0
    assert fleet.islands[1].ejections == 1
