"""Render a run summary from a trace JSONL.

``python -m repro.obs.report <trace.jsonl> [--csv PREFIX]`` reconstructs,
from the structured records `repro.obs.trace` wrote during a search:

* **wall-clock by span** — per span name: calls, total seconds, share,
  and the first-call (jit-compile) vs steady-state split;
* **per-island generation timeline** — every ``island.generation`` span
  grouped by island, with ejections/kills inlined from the ledger;
* **Pareto progress** — a hypervolume proxy per generation from the
  ``ga.front`` events (exact 2-objective hypervolume against a reference
  point derived from the run's own worst front corner; a product proxy
  for 3+ objectives);
* **cache-hit-rate curve** — per fleet round from ``fleet.fit`` events
  (memo hits) and per evaluation batch from ``eval.batch`` (EvalCache
  hits);
* **executables** — the executable observatory rebuilt post-hoc from
  ``prof.executable`` / ``prof.compile`` events and the ``key`` attrs on
  dispatch spans: per static-shape key, dispatch counts, compile
  events/seconds (XLA recompiles are keys compiling more than once),
  FLOPs/bytes from the captured cost analysis — with top-N cuts by
  compile time, FLOPs and dispatch count;
* **padding waste** — packing efficiency of the bucketed executables
  from ``netlist_sim.padding`` / ``eval.padding`` events: real vs padded
  lanes/rows/slots and the waste share each bucket family pays for
  executable reuse;
* **recompiles per generation** — backend-compile events bucketed into
  the ``island.generation`` span intervals, making a recompile storm in
  a warm search visible at a glance;
* **fault/quarantine ledger** — the complete chronological stream of
  ejections, kills, migrations, quarantines, preemptions, checkpoint
  writes and cache salvages (the in-memory rings keep only a tail; the
  trace keeps everything).

``--csv PREFIX`` additionally writes ``PREFIX.spans.csv``,
``PREFIX.generations.csv``, ``PREFIX.cache.csv``, ``PREFIX.ledger.csv``,
``PREFIX.executables.csv`` and ``PREFIX.padding.csv`` for downstream
tooling. Rendering is deterministic for a given trace file, so a
committed trace has a golden report (tested).
"""
from __future__ import annotations

import argparse
import csv
import sys
from collections import defaultdict
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.obs.trace import read_trace

# events rendered into the fault/quarantine ledger, in stream order
# (runtime.checkpoint / runtime.resume are *spans* and join the ledger
# from the span stream with their durations)
LEDGER_EVENTS = ("fleet.straggler_ejected", "fleet.killed",
                 "fleet.all_straggle_waived", "fleet.migration",
                 "eval.quarantine", "runtime.preempt", "cache.salvage")


def _attrs(rec: Dict[str, Any]) -> Dict[str, Any]:
    return rec.get("attrs") or {}


# ---------------------------------------------------------------------------
# aggregation
# ---------------------------------------------------------------------------


def span_table(records: Sequence[Dict]) -> List[Dict]:
    """Per span name: calls, total/compile/steady seconds, errors."""
    agg: Dict[str, Dict] = defaultdict(
        lambda: {"calls": 0, "total_s": 0.0, "compile_s": 0.0,
                 "steady_s": 0.0, "errors": 0})
    for r in records:
        if r.get("kind") != "span":
            continue
        a = agg[r["name"]]
        a["calls"] += 1
        dur = float(r.get("dur", 0.0))
        a["total_s"] += dur
        if _attrs(r).get("first"):
            a["compile_s"] += dur
        else:
            a["steady_s"] += dur
        if "error" in r:
            a["errors"] += 1
    rows = [{"name": k, **v} for k, v in agg.items()]
    rows.sort(key=lambda r: (-r["total_s"], r["name"]))
    return rows


def island_timelines(records: Sequence[Dict]) -> Dict[int, List[Dict]]:
    """island -> chronological [{round, generation, dur, error?}]."""
    out: Dict[int, List[Dict]] = defaultdict(list)
    for r in records:
        if r.get("kind") == "span" and r["name"] == "island.generation":
            a = _attrs(r)
            if "island" not in a:
                continue
            out[int(a["island"])].append({
                "round": a.get("round"), "generation": a.get("generation"),
                "ts": r.get("ts"), "dur": float(r.get("dur", 0.0)),
                "error": r.get("error")})
    for isl in out.values():
        isl.sort(key=lambda e: (e["ts"] if e["ts"] is not None else 0.0))
    return dict(sorted(out.items()))


def _hv_2d(points: Sequence[Sequence[float]],
           ref: Sequence[float]) -> float:
    """Exact 2-objective (minimization) hypervolume against ``ref``."""
    pts = sorted({(float(p[0]), float(p[1])) for p in points
                  if p[0] < ref[0] and p[1] < ref[1]})
    hv, prev_y = 0.0, float(ref[1])
    for x, y in pts:                        # x ascending
        if y < prev_y:
            hv += (ref[0] - x) * (prev_y - y)
            prev_y = y
    return hv


def hypervolume_progress(records: Sequence[Dict]) -> List[Dict]:
    """Per ``ga.front`` event: a hypervolume proxy over the recorded first
    front, against a reference point 5% beyond the run's own worst corner
    (so the proxy is comparable within a run, monotone as fronts improve)."""
    fronts = []
    for r in records:
        if r.get("kind") == "event" and r["name"] == "ga.front":
            a = _attrs(r)
            if a.get("front"):
                fronts.append((r.get("ts", 0.0), a))
    if not fronts:
        return []
    k = len(fronts[0][1]["front"][0])
    ref = [1.05 * max(max(float(p[j]) for p in a["front"])
                      for _, a in fronts) + 1e-9 for j in range(k)]
    out = []
    for ts, a in fronts:
        pts = a["front"]
        if k == 2:
            hv = _hv_2d(pts, ref)
        else:                               # 3+ objectives: product proxy
            hv = 1.0
            for j in range(k):
                hv *= max(ref[j] - min(float(p[j]) for p in pts), 0.0)
        out.append({"ts": ts, "island": a.get("island"),
                    "round": a.get("round"),
                    "generation": a.get("generation"),
                    "front_size": len(pts), "hv_proxy": hv,
                    "best_acc": a.get("best_acc"),
                    "min_cost": a.get("min_cost")})
    return out


def cache_curve(records: Sequence[Dict]) -> List[Dict]:
    """Hit-rate per fleet round (memo) and per eval batch (EvalCache)."""
    per_round: Dict[int, Dict[str, int]] = defaultdict(
        lambda: {"requested": 0, "memoized": 0, "fitted": 0})
    batches: List[Dict] = []
    for r in records:
        if r.get("kind") != "event":
            continue
        a = _attrs(r)
        if r["name"] == "fleet.fit" and "round" in a:
            d = per_round[int(a["round"])]
            d["requested"] += int(a.get("requested", 0))
            d["memoized"] += int(a.get("memoized", 0))
            d["fitted"] += int(a.get("fitted", 0))
        elif r["name"] == "eval.batch":
            batches.append({"ts": r.get("ts"),
                            "requested": int(a.get("requested", 0)),
                            "hits": int(a.get("hits", 0)),
                            "evaluated": int(a.get("evaluated", 0))})
    rounds = [{"round": k, **v,
               "hit_rate": (v["memoized"] / v["requested"]
                            if v["requested"] else 0.0)}
              for k, v in sorted(per_round.items())]
    return rounds + [{"batch": i, **b,
                      "hit_rate": (b["hits"] / b["requested"]
                                   if b["requested"] else 0.0)}
                     for i, b in enumerate(batches)]


_EXEC_CAPTURE_FIELDS = ("signature", "flops", "bytes_accessed",
                        "generated_code_size_in_bytes",
                        "argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes")


def executables(records: Sequence[Dict]) -> List[Dict]:
    """Rebuild the executable registry from the trace: ``prof.executable``
    events carry the first-compile capture, ``prof.compile`` events the
    backend-compile accounting, and dispatch spans (any span with a
    ``key`` attr) the per-key dispatch count and wall-clock. Compiles
    with no in-flight dispatch aggregate under key ``(unattributed)``."""
    ex: Dict[str, Dict] = {}

    def rec(key: str, site: Optional[str] = None) -> Dict:
        r = ex.get(key)
        if r is None:
            r = ex[key] = {"key": key, "site": site or "", "dispatches": 0,
                           "total_s": 0.0, "compiles": 0, "compile_s": 0.0,
                           "aot_compiles": 0, "aot_compile_s": 0.0}
        if site and not r["site"]:
            r["site"] = site
        return r

    for r in records:
        a = _attrs(r)
        if r.get("kind") == "span" and "key" in a:
            e = rec(a["key"], r["name"])
            e["dispatches"] += 1
            e["total_s"] += float(r.get("dur", 0.0))
        elif r.get("kind") != "event":
            continue
        elif r["name"] == "prof.compile":
            e = rec(a.get("key") or "(unattributed)", a.get("site"))
            pre = "aot_" if a.get("aot") else ""
            e[pre + "compiles"] += 1
            e[pre + "compile_s"] += float(a.get("seconds", 0.0))
        elif r["name"] == "prof.executable":
            e = rec(a["key"], a.get("site"))
            for f in _EXEC_CAPTURE_FIELDS:
                if f in a:
                    e[f] = a[f]
    rows = sorted(ex.values(),
                  key=lambda e: (-e["compile_s"], -e["dispatches"],
                                 e["key"]))
    return rows


def padding_table(records: Sequence[Dict]) -> List[Dict]:
    """Aggregate padding-waste accounting per bucket family: the netlist
    engines' NOP lanes / repeated candidates / repeated batch rows
    (``netlist_sim.padding``) and the QAT evaluator's population-bucket
    slack (``eval.padding``)."""
    agg: Dict[Tuple[str, str], Dict] = {}
    for r in records:
        if r.get("kind") != "event":
            continue
        a = _attrs(r)
        if r["name"] == "netlist_sim.padding":
            k = ("netlist_sim." + str(a.get("engine")), "lanes")
            d = agg.setdefault(k, {"launches": 0, "used": 0, "total": 0})
            d["launches"] += 1
            d["used"] += int(a.get("lanes_used", 0))
            d["total"] += int(a.get("lanes_total", 0))
            k2 = ("netlist_sim." + str(a.get("engine")), "rows")
            d2 = agg.setdefault(k2, {"launches": 0, "used": 0, "total": 0})
            d2["launches"] += 1
            d2["used"] += int(a.get("rows_real", 0))
            d2["total"] += int(a.get("rows_total", 0))
        elif r["name"] == "eval.padding":
            k = (f"eval.finetune[{a.get('dataset')}]", "specs")
            d = agg.setdefault(k, {"launches": 0, "used": 0, "total": 0})
            d["launches"] += 1
            d["used"] += int(a.get("specs_real", 0))
            d["total"] += int(a.get("specs_total", 0))
    return [{"site": site, "dim": dim, **d,
             "waste_pct": (100.0 * (1.0 - d["used"] / d["total"])
                           if d["total"] else 0.0)}
            for (site, dim), d in sorted(agg.items())]


def recompile_timeline(records: Sequence[Dict]) -> List[Dict]:
    """Dispatch-triggered backend compiles per ``island.generation``
    interval (profiler-initiated AOT captures excluded). Compiles outside
    every generation span (warm-up, checkpoint/resume, report glue) land
    in the ``(outside generations)`` row."""
    gens = []
    for r in records:
        if r.get("kind") == "span" and r["name"] == "island.generation":
            a = _attrs(r)
            ts = float(r.get("ts", 0.0))
            gens.append({"start": ts, "end": ts + float(r.get("dur", 0.0)),
                         "island": a.get("island"),
                         "round": a.get("round"),
                         "generation": a.get("generation"),
                         "compiles": 0, "compile_s": 0.0})
    gens.sort(key=lambda g: g["start"])
    outside = {"island": None, "round": None, "generation": None,
               "compiles": 0, "compile_s": 0.0}
    any_compiles = False
    for r in records:
        if r.get("kind") != "event" or r["name"] != "prof.compile":
            continue
        a = _attrs(r)
        if a.get("aot"):
            continue
        any_compiles = True
        ts = float(r.get("ts", 0.0))
        for g in gens:
            if g["start"] <= ts <= g["end"]:
                g["compiles"] += 1
                g["compile_s"] += float(a.get("seconds", 0.0))
                break
        else:
            outside["compiles"] += 1
            outside["compile_s"] += float(a.get("seconds", 0.0))
    if not any_compiles:
        return []
    rows = [{k: g[k] for k in ("island", "round", "generation", "compiles",
                               "compile_s")} for g in gens]
    rows.append(outside)
    return rows


def ledger(records: Sequence[Dict]) -> List[Dict]:
    out = []
    for r in records:
        if r.get("kind") == "event" and r["name"] in LEDGER_EVENTS:
            out.append({"ts": r.get("ts", 0.0), "name": r["name"],
                        **_attrs(r)})
        elif (r.get("kind") == "span"
              and r["name"] in ("runtime.checkpoint", "runtime.resume")):
            out.append({"ts": r.get("ts", 0.0), "name": r["name"],
                        "dur": r.get("dur"), **_attrs(r)})
    out.sort(key=lambda e: e["ts"])
    return out


# ---------------------------------------------------------------------------
# rendering
# ---------------------------------------------------------------------------


def _fmt_attrs(d: Dict[str, Any], skip=("ts",)) -> str:
    parts = []
    for k, v in d.items():
        if k in skip or v is None:
            continue
        if isinstance(v, float):
            v = f"{v:.4g}"
        parts.append(f"{k}={v}")
    return " ".join(parts)


def render(records: Sequence[Dict], damaged: int = 0,
           source: str = "trace") -> str:
    lines: List[str] = []
    spans = span_table(records)
    wall = max((r.get("ts", 0.0) + float(r.get("dur", 0.0))
                for r in records if isinstance(r.get("ts"), (int, float))),
               default=0.0)
    n_span = sum(1 for r in records if r.get("kind") == "span")
    n_event = sum(1 for r in records if r.get("kind") == "event")
    lines.append(f"== repro.obs run report: {source} ==")
    lines.append(f"records: {len(records)} ({n_span} spans, {n_event} "
                 f"events), wall-clock {wall:.3f}s"
                 + (f", {damaged} damaged line(s) skipped" if damaged
                    else ""))

    lines.append("")
    lines.append("-- wall-clock by span --")
    lines.append(f"{'span':<24}{'calls':>7}{'total_s':>10}{'share':>8}"
                 f"{'compile_s':>11}{'steady_s':>10}{'errors':>8}")
    total_all = sum(r["total_s"] for r in spans) or 1.0
    for r in spans:
        lines.append(f"{r['name']:<24}{r['calls']:>7}{r['total_s']:>10.4f}"
                     f"{r['total_s'] / total_all:>8.1%}"
                     f"{r['compile_s']:>11.4f}{r['steady_s']:>10.4f}"
                     f"{r['errors']:>8}")

    tl = island_timelines(records)
    lines.append("")
    lines.append("-- per-island generation timeline --")
    if not tl:
        lines.append("(no island.generation spans)")
    for isl, gens in tl.items():
        ok = [g for g in gens if not g["error"]]
        errs = [g for g in gens if g["error"]]
        tot = sum(g["dur"] for g in gens)
        lines.append(f"island {isl}: {len(ok)} generation(s), "
                     f"{len(errs)} failed, {tot:.4f}s")
        for g in gens:
            tag = f"  r{g['round']} g{g['generation']} {g['dur']*1e3:8.2f}ms"
            if g["error"]:
                tag += f"  !{g['error']}"
            lines.append(tag)

    hv = hypervolume_progress(records)
    lines.append("")
    lines.append("-- pareto progress (hypervolume proxy) --")
    if not hv:
        lines.append("(no ga.front events with front objectives)")
    for h in hv:
        where = (f"island {h['island']} " if h["island"] is not None else "")
        lines.append(f"{where}gen {h['generation']}: hv={h['hv_proxy']:.6g} "
                     f"front={h['front_size']} "
                     f"best_acc={h['best_acc']:.4f} "
                     f"min_cost={h['min_cost']:.4g}"
                     if h["best_acc"] is not None else
                     f"{where}gen {h['generation']}: "
                     f"hv={h['hv_proxy']:.6g} front={h['front_size']}")

    cc = cache_curve(records)
    lines.append("")
    lines.append("-- cache hit rate --")
    if not cc:
        lines.append("(no fleet.fit / eval.batch events)")
    for c in cc:
        if "round" in c:
            lines.append(f"round {c['round']}: {c['memoized']}/"
                         f"{c['requested']} memo hits "
                         f"({c['hit_rate']:.1%}), {c['fitted']} fitted")
        else:
            lines.append(f"batch {c['batch']}: {c['hits']}/{c['requested']} "
                         f"cache hits ({c['hit_rate']:.1%}), "
                         f"{c['evaluated']} evaluated")

    ex = executables(records)
    lines.append("")
    lines.append("-- executables (observatory) --")
    if not ex:
        lines.append("(no profiled dispatches: run with REPRO_TRACE=1)")
    else:
        n_comp = sum(e["compiles"] for e in ex)
        comp_s = sum(e["compile_s"] for e in ex)
        n_disp = sum(e["dispatches"] for e in ex)
        recomp = sum(1 for e in ex if e["compiles"] > 1)
        lines.append(f"{len(ex)} executable key(s), {n_disp} dispatches, "
                     f"{n_comp} backend compile(s) ({comp_s:.3f}s), "
                     f"{recomp} key(s) recompiled")

        def _ex_row(e):
            flops = e.get("flops")
            return (f"  {e['site']:<28}{e['dispatches']:>6}"
                    f"{e['compiles']:>5}{e['compile_s']:>9.3f}"
                    f"{e['total_s']:>9.3f}"
                    + (f"{flops:>12.3g}" if flops is not None
                       else f"{'-':>12}")
                    + f"  {e['key'][:40]}")

        hdr = (f"  {'site':<28}{'disp':>6}{'comp':>5}{'comp_s':>9}"
               f"{'disp_s':>9}{'flops':>12}  key")
        for title, keyfn in (
                ("top by compile time", lambda e: -e["compile_s"]),
                ("top by flops", lambda e: -(e.get("flops") or 0.0)),
                ("top by dispatches", lambda e: -e["dispatches"])):
            lines.append(f" {title}:")
            lines.append(hdr)
            for e in sorted(ex, key=keyfn)[:5]:
                lines.append(_ex_row(e))

    pad = padding_table(records)
    lines.append("")
    lines.append("-- padding waste (bucketed-executable overhead) --")
    if not pad:
        lines.append("(no netlist_sim.padding / eval.padding events)")
    else:
        lines.append(f"{'site':<28}{'dim':>6}{'launches':>10}{'used':>12}"
                     f"{'total':>12}{'waste':>8}")
        for p in pad:
            lines.append(f"{p['site']:<28}{p['dim']:>6}{p['launches']:>10}"
                         f"{p['used']:>12}{p['total']:>12}"
                         f"{p['waste_pct']:>7.1f}%")

    rt = recompile_timeline(records)
    lines.append("")
    lines.append("-- recompiles per generation --")
    if not rt:
        lines.append("(no prof.compile events)")
    for row in rt:
        where = ("(outside generations)" if row["generation"] is None else
                 f"island {row['island']} r{row['round']} "
                 f"g{row['generation']}")
        lines.append(f"{where:<28}{row['compiles']:>4} compile(s) "
                     f"{row['compile_s']:>8.3f}s")

    led = ledger(records)
    lines.append("")
    lines.append("-- fault/quarantine ledger --")
    if not led:
        lines.append("(clean run: no faults, checkpoints or quarantines)")
    for e in led:
        extra = _fmt_attrs({k: v for k, v in e.items()
                            if k not in ("ts", "name")})
        lines.append(f"[{e['ts']:10.4f}s] {e['name']}"
                     + (f"  {extra}" if extra else ""))
    lines.append("")
    return "\n".join(lines)


def write_csvs(records: Sequence[Dict], prefix: str) -> List[Path]:
    """PREFIX.spans/.generations/.cache/.ledger .csv — the machine-readable
    mirror of the report sections."""
    out: List[Path] = []

    def dump(name: str, rows: List[Dict]):
        p = Path(f"{prefix}.{name}.csv")
        keys: List[str] = []
        for r in rows:
            for k in r:
                if k not in keys:
                    keys.append(k)
        with open(p, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys)
            w.writeheader()
            w.writerows(rows)
        out.append(p)

    dump("spans", span_table(records))
    gens = [{"island": isl, **g}
            for isl, gens in island_timelines(records).items()
            for g in gens]
    dump("generations", gens)
    dump("cache", cache_curve(records))
    dump("ledger", ledger(records))
    dump("executables", executables(records))
    dump("padding", padding_table(records))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a run summary from a repro.obs trace JSONL.")
    ap.add_argument("trace", help="path to the trace .jsonl")
    ap.add_argument("--csv", metavar="PREFIX", default=None,
                    help="also write PREFIX.{spans,generations,cache,"
                         "ledger,executables,padding}.csv")
    args = ap.parse_args(argv)
    records, damaged = read_trace(args.trace)
    print(render(records, damaged, source=args.trace))
    if args.csv:
        for p in write_csvs(records, args.csv):
            print(f"wrote {p}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
