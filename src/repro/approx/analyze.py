"""Interval-arithmetic worst-case error analysis of approximated netlists.

For every node of a (possibly transformed) netlist we bound the deviation
``approx_value - exact_value`` of the value it computes from the value the
exact reference circuit (`minimize.integer_forward` semantics) would have
computed at the corresponding point. Sources of error:

* a node's local ``err_lo/err_hi`` annotation — set by rewrite passes for
  deviations the structure cannot show (a rounded multiplier coefficient);
* TRUNC's intrinsic floor-truncation error ``[-(2^k - 1), 0]``.

Propagation rules (exact interval arithmetic over Python ints — no
overflow, no float rounding):

  SHL   e << k                      ADD   ea + eb
  SUB   ea - eb                     NEG   [-eh, -el]
  TRUNC e + [-(2^k - 1), 0]         RELU  [min(el, 0), max(eh, 0)]

The RELU rule holds because relu is 1-Lipschitz and monotone:
relu(x + e) - relu(x) is bounded by e on one side and can collapse to 0 on
the other, never overshooting in either direction. Everything is
worst-case: the bound is sound for *any* input, which is what lets the
budgeted pass search promise a logit-error ceiling without simulating.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.circuit import ir

Interval = Tuple[int, int]


def propagate_errors(net: ir.Netlist) -> List[Interval]:
    """Cumulative worst-case error interval per node (Python-int exact)."""
    out: List[Interval] = []
    for n in net.nodes:
        if n.op in (ir.Op.CONST, ir.Op.INPUT, ir.Op.ARGMAX):
            lo, hi = 0, 0
        elif n.op == ir.Op.SHL:
            al, ah = out[n.args[0]]
            lo, hi = al << n.shift, ah << n.shift
        elif n.op == ir.Op.TRUNC:
            al, ah = out[n.args[0]]
            lo, hi = al - ((1 << n.shift) - 1), ah
        elif n.op == ir.Op.ADD:
            (al, ah), (bl, bh) = out[n.args[0]], out[n.args[1]]
            lo, hi = al + bl, ah + bh
        elif n.op == ir.Op.SUB:
            (al, ah), (bl, bh) = out[n.args[0]], out[n.args[1]]
            lo, hi = al - bh, ah - bl
        elif n.op == ir.Op.NEG:
            al, ah = out[n.args[0]]
            lo, hi = -ah, -al
        elif n.op == ir.Op.RELU:
            al, ah = out[n.args[0]]
            lo, hi = min(al, 0), max(ah, 0)
        else:                                    # pragma: no cover
            raise ValueError(f"unknown op {n.op}")
        out.append((lo + n.err_lo, hi + n.err_hi))
    return out


def _max_abs(errs: List[Interval], ids) -> int:
    return max((max(abs(errs[i][0]), abs(errs[i][1])) for i in ids),
               default=0)


def logit_error_bound(net: ir.Netlist) -> int:
    """Worst-case |approx - exact| over the integer logits (the last
    layer's pre-activation nodes), in logit LSBs."""
    return _max_abs(propagate_errors(net), net.output_ids)


def decision_error_bound(net: ir.Netlist) -> int:
    """Worst-case error at the argmax comparator inputs — includes any
    comparator-input truncation the logit nodes themselves don't see. An
    argmax decision can only flip when two exact logits are closer than
    twice this bound."""
    errs = propagate_errors(net)
    if net.argmax_id is None:
        return _max_abs(errs, net.output_ids)
    return _max_abs(errs, net.nodes[net.argmax_id].args)
