"""Clustered (codebook) matmul: y = x @ W where W[k, n] = codebook[k, idx[k, n]].

The paper's weight clustering on TPU (DESIGN.md §3): HBM stores one small int
index per weight (ceil(log2(n_clusters)) bits; int8 here) plus per-row
codebooks. The (bk, bn) weight tile is *reconstructed in VMEM* via a one-hot
contraction against the codebook tile — MXU-friendly (a (bn, C) x (C,) row
product per k), no lane gathers. HBM weight traffic: 1 byte/weight + tiny
codebooks instead of 2 bytes/weight, independent of cluster count.

Per-input-row codebooks ((K, C)) exactly mirror `core.clustering`'s
multiplier-sharing form.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams


def _cmm_kernel(x_ref, idx_ref, cb_ref, o_ref, acc_ref, *, k_steps: int,
                n_clusters: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    idx = idx_ref[...]                                    # (bk, bn) int
    cb = cb_ref[...].astype(jnp.float32)                  # (bk, C)
    # one-hot reconstruction: w[k, n] = sum_c (idx[k,n]==c) * cb[k,c]
    iota = jax.lax.broadcasted_iota(jnp.int32, idx.shape + (n_clusters,), 2)
    onehot = (idx[..., None] == iota).astype(jnp.float32)  # (bk, bn, C)
    w = jnp.sum(onehot * cb[:, None, :], axis=-1)          # (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w,
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def clustered_matmul_pallas(x, idx, codebook, *, block_m: int = 128,
                            block_n: int = 128, block_k: int = 128,
                            interpret: bool = False):
    """x: (M, K); idx: (K, N) int8/int32; codebook: (K, C) f32."""
    M, K = x.shape
    K2, N = idx.shape
    C = codebook.shape[1]
    assert K == K2 and codebook.shape[0] == K
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    k_steps = K // block_k
    grid = (M // block_m, N // block_n, k_steps)

    return pl.pallas_call(
        functools.partial(_cmm_kernel, k_steps=k_steps, n_clusters=C),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_k, C), lambda i, j, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, idx.astype(jnp.int32), codebook)
