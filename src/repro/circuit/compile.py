"""Lower a compiled (quantized + pruned + clustered) MLP to a bespoke
netlist.

The lowering is the published bespoke recipe (Mubarik MICRO'20; Armeniakos
DATE'22) made explicit, one node at a time:

* **CSD multipliers** — each non-zero integer coefficient becomes a
  shift-add network over the canonical signed-digit recoding
  (`hw_model.csd_digits`, the same Avizienis recurrence the analytic model
  counts): one SHL wire per non-zero digit, digit-1 ADD/SUB gates, at most
  one NEG when every digit is negative. A power-of-two coefficient is pure
  wiring.
* **Pruning-mask elision** — a zero coefficient lowers to *nothing*: no
  product node, no adder-tree operand. The mask is realized by absence.
* **Cluster fan-out sharing** — with per-input clustering, one product
  subnet is built per (input row, distinct non-zero cluster actually
  referenced by a surviving weight); every weight in the row that maps to
  that cluster taps the shared root. This is exactly the `_used_clusters`
  selection `hw_model` prices.
* **Adder trees** — per neuron, a balanced binary ADD tree over its
  surviving products, then one bias ADD against the hardwired integer bias
  (`minimize.integer_biases`). A fully-pruned neuron keeps its bias add
  (the accumulator register is printed regardless), matching the analytic
  `max(operands-1, 0) + 1` count.
* **ReLU** per hidden neuron, **ARGMAX** comparator tree over the logits.

The netlist's integer semantics equal `minimize.integer_forward` exactly
(tested bit-for-bit); its structural cost equals `hw_model.mlp_cost`
layer-by-layer (tested count-for-count).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core import hw_model as HW
from repro.core import minimize as MZ
from repro.circuit import ir


def _lower_const_mult(net: ir.Netlist, x: int, coeff: int, *, layer: int,
                      unit: Tuple[int, ...]) -> int:
    """One bespoke constant multiplier: x * coeff as a CSD shift-add
    network. Returns the product root node id. coeff must be non-zero."""
    assert coeff != 0
    digits = HW.csd_digits(int(coeff))
    # lead with a positive digit when one exists so the chain starts as a
    # plain shift; an all-negative recoding (e.g. -5 -> -4 -1) needs one NEG
    lead = next((i for i, (_, s) in enumerate(digits) if s > 0), 0)
    digits = [digits[lead]] + digits[:lead] + digits[lead + 1:]
    tags = dict(role=ir.ROLE_MULT, layer=layer, unit=unit)
    p0, s0 = digits[0]
    node = net.shl(x, p0, **tags)
    if s0 < 0:
        node = net.neg(node, **tags)
    for p, s in digits[1:]:
        term = net.shl(x, p, **tags)
        node = (net.add(node, term, **tags) if s > 0
                else net.sub(node, term, **tags))
    net.nodes[node].product_root = True
    return node


def _tree_sum(net: ir.Netlist, terms: List[int], *, layer: int,
              unit: Tuple[int, ...]) -> Optional[int]:
    """Balanced binary adder tree over ``terms`` (operands - 1 ADDs)."""
    if not terms:
        return None
    tags = dict(role=ir.ROLE_TREE, layer=layer, unit=unit)
    while len(terms) > 1:
        nxt = [net.add(a, b, **tags)
               for a, b in zip(terms[::2], terms[1::2])]
        if len(terms) % 2:
            nxt.append(terms[-1])
        terms = nxt
    return terms[0]


def _lower_layer(net: ir.Netlist, acts: List[int], q: np.ndarray,
                 b_int: np.ndarray,
                 cluster: Optional[Tuple[np.ndarray, np.ndarray]], *,
                 layer: int, relu: bool) -> Tuple[List[int], List[int]]:
    """Lower one dense layer. Returns (activation node ids, pre node ids)."""
    q = np.asarray(q, np.int64)
    d_in, d_out = q.shape
    active = q != 0

    # ---- products: one subnet per active weight, or one per used
    # non-zero cluster with fan-out sharing --------------------------------
    if cluster is not None:
        idx, cb = np.asarray(cluster[0]), np.asarray(cluster[1], np.int64)
        shared: dict = {}
        for j in range(d_in):
            used = np.unique(idx[j][active[j]])
            for m in used:
                if cb[j, m] != 0:
                    shared[(j, int(m))] = _lower_const_mult(
                        net, acts[j], int(cb[j, m]), layer=layer,
                        unit=(j, int(m)))

        def product(j: int, k: int) -> int:
            return shared[(j, int(idx[j, k]))]
    else:
        def product(j: int, k: int) -> int:
            return _lower_const_mult(net, acts[j], int(q[j, k]),
                                     layer=layer, unit=(j, k))

    # ---- per-neuron adder tree + bias add --------------------------------
    pres: List[int] = []
    for k in range(d_out):
        terms = [product(j, k) for j in range(d_in) if active[j, k]]
        root = _tree_sum(net, terms, layer=layer, unit=(k,))
        if root is None:
            root = net.const(0)            # fully-pruned neuron: bias only
        bias = net.const(int(b_int[k]))
        pres.append(net.add(root, bias, role=ir.ROLE_BIAS, layer=layer,
                            unit=(k,)))
    net.layer_pre_ids.append(pres)

    if not relu:
        return pres, pres
    outs = [net.relu(p, role=ir.ROLE_RELU, layer=layer, unit=(k,))
            for k, p in enumerate(pres)]
    return outs, pres


def compile_netlist(c: "MZ.CompiledMLP") -> ir.Netlist:
    """CompiledMLP (integer weights + codebooks + scales from the QAT
    compile) -> bespoke netlist. The returned netlist is validated: args
    in topo order, every width <= 62 bits (exact int64 simulation)."""
    from repro.obs import metrics as MT
    from repro.obs import trace as TR
    with TR.span("circuit.compile") as sp:
        net = ir.Netlist(in_bits=c.input_bits, w_bits=c.w_bits)
        acts = [net.input(j) for j in range(c.q_layers[0].shape[0])]
        b_ints = MZ.integer_biases(c)
        n_layers = len(c.q_layers)
        for i, (q, b) in enumerate(zip(c.q_layers, b_ints)):
            acts, _ = _lower_layer(net, acts, q, b, c.clusters[i], layer=i,
                                   relu=(i < n_layers - 1))
        net.output_ids = list(net.layer_pre_ids[-1])
        net.argmax(net.output_ids)
        net.validate()
        sp.set(nodes=len(net.nodes))
    MT.counter("circuit.compiles").inc()
    from repro.verify.diagnostics import verify_enabled
    if verify_enabled():
        # the compiler's own output contract, beyond structural soundness:
        # microarchitectural conventions hold (strict), the netlist is
        # exact (no TRUNC, no error annotations) and fully live
        from repro.verify.netlist import check_netlist
        check_netlist(net, strict=True, expect_exact=True, expect_dce=True)
    return net


def compile_spec(cfg, spec, *, epochs: int = 150, seed: int = 0
                 ) -> Tuple[ir.Netlist, "MZ.CompiledMLP"]:
    """Convenience end-to-end path: pretrain (cached) -> QAT finetune under
    ``spec`` -> bespoke compile -> netlist."""
    params0, (xtr, ytr, _, _) = MZ.pretrain(cfg, seed=seed)
    masks = MZ.make_masks(params0, spec)
    params = MZ.qat_finetune(params0, spec, masks, xtr, ytr, epochs=epochs)
    compiled = MZ.compile_bespoke(params, spec, masks)
    return compile_netlist(compiled), compiled
