"""Quantized matmul: y = x @ (w_q * scale), dequantized tile-by-tile in VMEM.

The paper's quantization on TPU (DESIGN.md §3): weights live in HBM at
`bits`/8 bytes each; the (bk, bn) int tile is streamed to VMEM, dequantized
on the VPU against per-column scales, and fed to the MXU in fp32/bf16. HBM
traffic for weights drops by 2/(bits/8)x vs bf16 — the decode-roofline win.

Grid: (M/bm, N/bn, K/bk), k innermost ("arbitrary" semantics), fp32
accumulator in VMEM scratch, output written on the last k step.
Block shapes are MXU-aligned (multiples of (8,128) tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams


def _qmm_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...].astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def quant_matmul_pallas(x, w_q, scales, *, block_m: int = 128,
                        block_n: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """x: (M, K) float; w_q: (K, N) int8 on a `bits` grid; scales: (N,) f32.
    M, K, N must be multiples of the block sizes (ops.py pads)."""
    M, K = x.shape
    K2, N = w_q.shape
    assert K == K2 and scales.shape == (N,)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    k_steps = K // block_k
    grid = (M // block_m, N // block_n, k_steps)

    return pl.pallas_call(
        functools.partial(_qmm_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_q, scales.reshape(1, N))
