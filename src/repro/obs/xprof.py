"""XLA-facing profiling primitives: compile listeners + artifact capture.

Two independent pieces, both usable without the ambient tracer:

* **compile accounting** — ``jax.monitoring`` fires
  ``/jax/core/compile/backend_compile_duration`` on every XLA backend
  compile (a single dispatch may fire several: main computation plus
  constant/donation subcomputations). :func:`add_sink` /
  :func:`count_compiles` fan those events out to in-process observers.
  One module-level listener is installed lazily and exactly once — jax
  only offers ``clear_event_listeners()`` (which would drop *everyone's*
  listeners), so ours stays registered and forwards to whatever sinks
  are currently attached; with no sinks it is one list check.

* **artifact capture** — :func:`capture_executable` runs a caller-supplied
  ``lower()`` thunk and reads ``cost_analysis()`` / ``memory_analysis()``
  off the AOT artifacts (`jax.stages.Lowered` / `Compiled`). On jax
  0.4.x the AOT compile cache is *not* shared with the dispatch cache,
  so ``lowered.compile()`` performs a real backend compile even for an
  executable the dispatch path already built; those profiler-initiated
  compiles run inside :func:`aot_scope` and reach sinks flagged
  ``aot=True`` so recompile accounting never blames the profiler for
  them. Backends returning ``None`` (or raising) for either analysis are
  tolerated — capture degrades to whatever fields exist.

Nothing here mutates the computation being profiled: capture reads
lowered artifacts, it never wraps or rewrites the jitted callable, so
profiling cannot perturb device-side numerics.
"""
from __future__ import annotations

import hashlib
import threading
from typing import Any, Callable, Dict, List, Optional

import jax
from jax import monitoring

EVENT_COMPILE = "/jax/core/compile/backend_compile_duration"

# memory_analysis() fields copied into capture dicts, in report order
_MEMORY_FIELDS = ("generated_code_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "temp_size_in_bytes")

_lock = threading.Lock()
_installed = False
_sinks: List[Callable[[float, bool], None]] = []
_aot = threading.local()            # .depth > 0 => profiler-initiated


def _in_aot_scope() -> bool:
    return getattr(_aot, "depth", 0) > 0


def _on_event_duration(event: str, duration: float, **kw) -> None:
    if event != EVENT_COMPILE or not _sinks:
        return
    aot = _in_aot_scope()
    for sink in list(_sinks):
        sink(duration, aot)


def install_listener() -> None:
    """Register the module's forwarding listener (idempotent)."""
    global _installed
    with _lock:
        if not _installed:
            monitoring.register_event_duration_secs_listener(
                _on_event_duration)
            _installed = True


def listener_installed() -> bool:
    return _installed


def add_sink(sink: Callable[[float, bool], None]) -> None:
    """Attach ``sink(seconds, aot)``; installs the listener on first use."""
    install_listener()
    with _lock:
        if sink not in _sinks:
            _sinks.append(sink)


def remove_sink(sink: Callable[[float, bool], None]) -> None:
    with _lock:
        if sink in _sinks:
            _sinks.remove(sink)


class aot_scope:
    """``with aot_scope():`` — backend compiles inside are profiler-initiated
    (AOT artifact capture) and reach sinks with ``aot=True``."""

    def __enter__(self):
        _aot.depth = getattr(_aot, "depth", 0) + 1
        return self

    def __exit__(self, *exc):
        _aot.depth -= 1
        return False


class CompileCount:
    """A sink accumulating backend-compile counts and seconds, split into
    dispatch-triggered vs profiler-initiated (AOT)."""
    __slots__ = ("compiles", "compile_s", "aot_compiles", "aot_compile_s")

    def __init__(self):
        self.compiles = 0
        self.compile_s = 0.0
        self.aot_compiles = 0
        self.aot_compile_s = 0.0

    def __call__(self, seconds: float, aot: bool) -> None:
        if aot:
            self.aot_compiles += 1
            self.aot_compile_s += seconds
        else:
            self.compiles += 1
            self.compile_s += seconds


class count_compiles:
    """``with count_compiles() as c:`` — count every XLA backend compile in
    the body (works with tracing off; benchmarks use it to assert warm
    phases compile nothing). ``c.compiles``/``c.compile_s`` exclude
    profiler-initiated AOT compiles, which land in ``c.aot_*``."""

    def __enter__(self) -> CompileCount:
        self._count = CompileCount()
        add_sink(self._count)
        return self._count

    def __exit__(self, *exc):
        remove_sink(self._count)
        return False


# ---------------------------------------------------------------------------
# AOT artifact capture
# ---------------------------------------------------------------------------


def signature_hash(lowered) -> str:
    """Stable short hash of the lowered input signature (abstract avals:
    shapes + dtypes), for cross-run executable identity."""
    try:
        sig = str(lowered.in_avals)
    except Exception:
        sig = repr(lowered)
    return hashlib.sha1(sig.encode()).hexdigest()[:12]


def _first_dict(obj) -> Optional[Dict[str, Any]]:
    """cost_analysis() is a dict on Lowered, a list of per-computation
    dicts on Compiled — normalize to the main computation's dict."""
    if isinstance(obj, dict):
        return obj
    if isinstance(obj, (list, tuple)) and obj and isinstance(obj[0], dict):
        return obj[0]
    return None


def capture_executable(lower: Callable[[], Any], *,
                       compile: bool = True) -> Dict[str, Any]:
    """Run a ``lower()`` thunk and read the artifact analyses.

    -> {"signature": ..., "flops": ..., "bytes_accessed": ...,
        <memory fields>} with only the fields the backend reported;
    ``{"error": <ExcName>}`` if lowering itself failed. ``compile=False``
    skips the (real, cache-missing on 0.4.x) AOT backend compile and the
    memory fields that need it.
    """
    out: Dict[str, Any] = {}
    try:
        with aot_scope():
            lowered = lower()
            out["signature"] = signature_hash(lowered)
            cost = _first_dict(lowered.cost_analysis())
            if compile:
                compiled = lowered.compile()
                cost = _first_dict(compiled.cost_analysis()) or cost
                mem = compiled.memory_analysis()
                if mem is not None:
                    for f in _MEMORY_FIELDS:
                        v = getattr(mem, f, None)
                        if v is not None:
                            out[f] = int(v)
            if cost:
                if "flops" in cost:
                    out["flops"] = float(cost["flops"])
                if "bytes accessed" in cost:
                    out["bytes_accessed"] = float(cost["bytes accessed"])
    except Exception as e:                          # noqa: BLE001
        # profiling must never take down the computation it observes
        out.setdefault("error", type(e).__name__)
    return out


def backend() -> str:
    return jax.default_backend()


__all__ = ["EVENT_COMPILE", "CompileCount", "aot_scope", "add_sink",
           "backend", "capture_executable", "count_compiles",
           "install_listener", "listener_installed", "remove_sink",
           "signature_hash"]
