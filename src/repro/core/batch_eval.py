"""Batched population evaluation — the GA's hot path, one jit instead of N.

`run_nsga2` spends essentially all of its time in per-candidate QAT
finetuning: the serial path traces and compiles a fresh `jax.jit` train
loop for every spec. This module stacks a whole population's genomes into
padded per-layer arrays (bits, cluster counts, pruning masks), and runs the
QAT finetune for all candidates in a single `jax.vmap`-over-`lax.scan`
jitted call against the shared pretrained weights. Compiled circuits are
then priced for the whole population at once through the vectorized
`hw_model.mlp_cost_batch`.

The dynamic (traced) spec transforms are written to match the serial
static-spec path operation-for-operation:

* quantization: integer `qmax` built by bit-shift (no float pow), same
  scale/round/clip sequence as `quantization.fake_quant`;
* clustering: padded Lloyd k-means over `K_MAX` slots with invalid slots
  masked to +inf distance — identical quantile init, identical argmin
  tie-breaking, so valid-slot centroids equal `clustering._kmeans_1d`'s;
* "off" genes (bits=None / clusters=None / sparsity=0) select the identity
  branch through `jnp.where`, multiplying by an all-ones mask.

A persistent on-disk `EvalCache` keyed by (dataset, seed, epochs,
spec.to_json()) makes resumed searches and repeated sweeps free.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
import tempfile
import warnings
from collections import OrderedDict
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.printed_mlp import PrintedMLPConfig
from repro.core import hw_model as HW
from repro.core import minimize as MZ
from repro.core.compression_spec import ModelMin
from repro.obs import metrics as MT
from repro.obs import prof as PF
from repro.obs import trace as TR

# Padded k-means slot count: must cover every cluster count the GA can emit
# (core.ga.CLUSTER_CHOICES tops out at 16).
K_MAX = 16


# ---------------------------------------------------------------------------
# evaluation quarantine
# ---------------------------------------------------------------------------

# Worst-case fitness for quarantined specs: finite (inf would poison
# crowding-distance normalization in NSGA-II) but dominated by every real
# candidate, so a quarantined spec can never reach a Pareto front.
QUARANTINE_AREA_MM2 = 1e9
QUARANTINE_POWER_MW = 1e9
QUARANTINE_DELAY_LEVELS = 10 ** 9


@dataclasses.dataclass
class QuarantineRecord:
    """Structured diagnostic for a spec whose evaluation failed.

    A failing candidate (netlist-sim ``OverflowError`` past the 62-bit
    budget, NaN accuracy out of a diverged QAT finetune, any compile
    exception) is retried once and then quarantined with worst-case
    fitness instead of aborting the whole generation — hours of search
    must not die because one genome broke the toolchain.
    """
    spec_json: str
    stage: str              # "compile" | "score"
    error: str              # exception class name
    message: str
    attempts: int


def _worst_case_result(spec: ModelMin) -> MZ.EvalResult:
    return MZ.EvalResult(spec, 0.0, QUARANTINE_AREA_MM2,
                         QUARANTINE_POWER_MW, 0,
                         delay_levels=QUARANTINE_DELAY_LEVELS)


# Fault-injection hook (repro.search.faults): called as hook(spec, attempt)
# at the top of every candidate-evaluation attempt and may raise. None in
# production — the check is a single attribute load.
_EVAL_FAULT_HOOK: Optional[Callable[[ModelMin, int], None]] = None


def set_eval_fault_hook(hook: Optional[Callable[[ModelMin, int], None]]
                        ) -> Optional[Callable]:
    """Install (or clear, with None) the per-candidate fault hook; returns
    the previous hook so callers can restore it."""
    global _EVAL_FAULT_HOOK
    prev, _EVAL_FAULT_HOOK = _EVAL_FAULT_HOOK, hook
    return prev


# ---------------------------------------------------------------------------
# dynamic-spec transforms (traced bits / cluster counts)
# ---------------------------------------------------------------------------


def _padded_kmeans_1d(x: jnp.ndarray, k: jnp.ndarray, k_max: int,
                      iters: int = 25):
    """`clustering._kmeans_1d` with a *traced* cluster count.

    Runs Lloyd iterations over `k_max` centroid slots; slots >= k are held
    at +inf distance so assignments, counts and centroid updates of the
    valid slots reproduce the static-k path exactly (same quantile init,
    same first-index argmin tie-breaking).
    """
    kf = k.astype(jnp.float32)
    slots = jnp.arange(k_max, dtype=jnp.float32)
    valid = slots < kf                                    # (k_max,)
    qs = jnp.clip((slots + 0.5) / kf, 0.0, 1.0)
    cent = jnp.quantile(x, qs)

    def step(cent, _):
        d = jnp.abs(x[:, None] - cent[None, :])           # (N, k_max)
        d = jnp.where(valid[None, :], d, jnp.inf)
        a = jnp.argmin(d, axis=1)
        one = jax.nn.one_hot(a, k_max, dtype=jnp.float32)
        cnt = one.sum(0)
        s = (one * x[:, None]).sum(0)
        new = jnp.where(cnt > 0, s / jnp.maximum(cnt, 1), cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    d = jnp.abs(x[:, None] - cent[None, :])
    d = jnp.where(valid[None, :], d, jnp.inf)
    a = jnp.argmin(d, axis=1).astype(jnp.int32)
    return cent, a


def _cluster_dyn(w: jnp.ndarray, k: jnp.ndarray, k_max: int = K_MAX):
    """Per-input cluster STE with traced k; k == 0 -> identity."""
    wd = jax.lax.stop_gradient(w)
    keff = jnp.maximum(k, 1)
    cent, idx = jax.vmap(
        lambda row: _padded_kmeans_1d(row, keff, k_max))(wd)
    wq = jnp.take_along_axis(cent, idx, axis=1)
    return w + jnp.where(k > 0, wq - wd, 0.0)


def _quant_dyn(w: jnp.ndarray, bits: jnp.ndarray):
    """Symmetric per-tensor fake-quant STE with traced bits; 0 -> identity.
    qmax is built by integer shift so traced bits give the exact same grid
    as `quantization.fake_quant`'s static python-float 2**(b-1)-1."""
    wd = jax.lax.stop_gradient(w)
    beff = jnp.maximum(bits, 2)
    qmax = ((jnp.left_shift(jnp.int32(1), beff - 1)) - 1).astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(wd)), 1e-8)
    scale = amax / qmax
    fq = jnp.clip(jnp.round(wd / scale), -qmax, qmax) * scale
    return w + jnp.where(bits > 0, fq - wd, 0.0)


# ---------------------------------------------------------------------------
# population stacking
# ---------------------------------------------------------------------------


def stack_specs(specs: Sequence[ModelMin]) -> Tuple[np.ndarray, np.ndarray]:
    """-> (bits (P, L) int32, clusters (P, L) int32); 0 encodes "off"."""
    bits = np.array([[l.bits or 0 for l in s.layers] for s in specs],
                    np.int32)
    ks = np.array([[l.clusters or 0 for l in s.layers] for s in specs],
                  np.int32)
    return bits, ks


def stack_masks(params0, specs: Sequence[ModelMin]):
    """Magnitude masks from the shared pretrained weights, in both layouts
    the engine needs, from ONE memoized computation per distinct
    (layer, sparsity):

    -> (stacked: per layer (P, d_in, d_out) float32 for the vmapped
        finetune (all-ones when a gene's sparsity is 0),
        serial: per spec, per layer bool mask or None — the exact
        convention `compile_bespoke` / `make_masks` use).
    """
    memo: Dict[Tuple[int, float], Optional[np.ndarray]] = {}

    def mask_for(i, layer, sparsity):
        key = (i, float(sparsity))
        if key not in memo:
            memo[key] = (np.asarray(MZ.P.magnitude_mask(layer["w"],
                                                        sparsity), bool)
                         if sparsity > 0 else None)
        return memo[key]

    layers = params0["layers"]
    serial = [[mask_for(i, layers[i], s.layers[i].sparsity)
               for i in range(len(layers))] for s in specs]
    stacked = [np.stack([np.ones(layers[i]["w"].shape, np.float32)
                         if row[i] is None else row[i].astype(np.float32)
                         for row in serial])
               for i in range(len(layers))]
    return stacked, serial


# ---------------------------------------------------------------------------
# the batched QAT finetune (one jit for the whole population)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("epochs", "lr", "k_max"))
def _population_finetune(params0, bits, ks, masks, x, y, *,
                         epochs: int, lr: float, k_max: int = K_MAX):
    """QAT-finetune P candidates in one vmapped lax.scan train loop.

    params0: shared pretrained pytree; bits/ks: (P, L) int32; masks: tuple
    of L arrays (P, d_in_i, d_out_i) float32. Returns the trained params
    pytree with a leading population axis on every leaf.
    """
    def train_one(bits_row, ks_row, masks_row):
        def t(i, w):
            w = w * masks_row[i]
            w = _cluster_dyn(w, ks_row[i], k_max)
            return _quant_dyn(w, bits_row[i])
        return MZ._train(params0, x, y, epochs=epochs, lr=lr, w_transform=t)

    return jax.vmap(train_one, in_axes=(0, 0, 0))(bits, ks, masks)


# ---------------------------------------------------------------------------
# persistent evaluation cache
# ---------------------------------------------------------------------------


def _salvage_entries(text: str) -> Dict[str, Dict]:
    """Best-effort recovery of ``"key": {...}`` pairs from a torn cache
    JSON. Walks the top-level object entry by entry (keys embed escaped
    spec JSON, so this uses the real JSON scanner, not a regex) and stops
    at the first undecodable span — every complete leading entry of a
    truncated file survives."""
    out: Dict[str, Dict] = {}
    decoder = json.JSONDecoder()
    i = text.find("{")
    if i < 0:
        return out
    i += 1
    n = len(text)
    while i < n:
        while i < n and text[i] in ", \t\r\n":
            i += 1
        if i >= n or text[i] != '"':
            break
        try:
            key, i = json.decoder.scanstring(text, i + 1)
            while i < n and text[i] in " \t\r\n":
                i += 1
            if i >= n or text[i] != ":":
                break
            i += 1
            while i < n and text[i] in " \t\r\n":
                i += 1
            val, i = decoder.raw_decode(text, i)
        except (ValueError, IndexError):
            break
        if isinstance(val, dict):
            out[key] = val
    return out


class EvalCache:
    """On-disk cache of spec evaluations with a bounded footprint.

    One JSON file, atomically replaced on flush; keys are
    "dataset|seed=S|epochs=E|spec.to_json()" (suffixed "|netlist" for
    netlist-exact evaluations — a different objective, never mixed with
    analytic entries; approximated specs carry their genes in the spec
    JSON and always live in the netlist keyspace) so resumed searches,
    repeated sweeps and the serial/batched paths all share results.
    ``flush`` re-reads and merges the on-disk file first, so concurrent
    sweep processes sharing a cache file union their entries instead of
    clobbering each other.

    ``max_entries`` caps the cache: every get/put stamps the entry with a
    monotonic access counter, and flush evicts the least-recently-used
    entries beyond the cap — a month of GA sweeps can't grow the file
    without bound. Entries written by pre-cap versions carry no stamp and
    are evicted first. A flush with no new entries and few refreshed
    stamps is a cheap no-op (recency persistence is batched every
    ``TOUCH_FLUSH_EVERY`` hits), so warm fully-cached sweeps don't rewrite
    a multi-MB JSON per generation.
    """

    TOUCH_FLUSH_EVERY = 64

    def __init__(self, path, max_entries: Optional[int] = 100_000):
        self.path = Path(path)
        self.max_entries = max_entries
        self._data: Dict[str, Dict] = self._read()
        self._clock = max((int(e.get("t", 0))
                           for e in self._data.values()), default=0)
        self._dirty = False           # un-persisted put()s
        self._touched = 0             # un-persisted recency stamps

    def _touch(self, entry: Dict) -> Dict:
        self._clock += 1
        entry["t"] = self._clock
        return entry

    def _read(self) -> Dict[str, Dict]:
        if not self.path.exists():
            return {}
        try:
            text = self.path.read_text()
        except OSError as e:
            # unreadable file must not kill a long search — start empty;
            # the next flush atomically replaces it
            warnings.warn(f"EvalCache {self.path} unreadable ({e}); "
                          "starting empty")
            return {}
        try:
            return json.loads(text)
        except json.JSONDecodeError as e:
            # torn/truncated file (crash mid-write on a non-atomic fs,
            # disk-full, fault injection): keep the damaged bytes for the
            # post-mortem and salvage every individually-parseable entry —
            # a multi-day cache must not be discarded over a torn tail
            data = _salvage_entries(text)
            backup = self.path.with_suffix(self.path.suffix + ".corrupt")
            try:
                backup.write_text(text)
            except OSError:
                pass                       # salvage still proceeds
            warnings.warn(f"EvalCache {self.path} corrupt ({e}); salvaged "
                          f"{len(data)} entries, damaged file backed up "
                          f"to {backup}")
            MT.counter("cache.salvages").inc()
            TR.event("cache.salvage", path=str(self.path),
                     salvaged=len(data))
            return data

    @staticmethod
    def key(dataset: str, seed: int, epochs: int, spec: ModelMin,
            netlist: bool = False) -> str:
        base = f"{dataset}|seed={seed}|epochs={epochs}|{spec.to_json()}"
        return base + "|netlist" if netlist else base

    def __len__(self):
        return len(self._data)

    def get(self, dataset: str, seed: int, epochs: int, spec: ModelMin,
            netlist: bool = False) -> Optional[MZ.EvalResult]:
        d = self._data.get(self.key(dataset, seed, epochs, spec, netlist))
        if d is None:
            MT.counter("cache.miss").inc()
            return None
        MT.counter("cache.hit").inc()
        self._touch(d)                  # LRU: a hit keeps the entry young
        self._touched += 1
        return MZ.EvalResult(ModelMin.from_json(d["spec"]), d["accuracy"],
                             d["area_mm2"], d["power_mw"],
                             d["n_multipliers"],
                             delay_levels=d.get("delay_levels"))

    def put(self, dataset: str, seed: int, epochs: int,
            r: MZ.EvalResult, netlist: bool = False) -> None:
        self._data[self.key(dataset, seed, epochs, r.spec, netlist)] = \
            self._touch({
                "spec": r.spec.to_json(), "accuracy": float(r.accuracy),
                "area_mm2": float(r.area_mm2), "power_mw": float(r.power_mw),
                "n_multipliers": int(r.n_multipliers),
                "delay_levels": (None if r.delay_levels is None
                                 else int(r.delay_levels))})
        self._dirty = True

    def flush(self) -> None:
        # nothing new and too few refreshed stamps to be worth a full
        # re-read/merge/rewrite: skip (recency persistence is best-effort)
        if not self._dirty and self._touched < self.TOUCH_FLUSH_EVERY:
            return
        with TR.span("cache.flush", entries=len(self._data)):
            MT.counter("cache.flushes").inc()
            self._flush_locked()

    def _flush_locked(self) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        # merge concurrent writers under an flock'd sidecar: entries
        # flushed by another process since our last read survive; on a key
        # conflict ours wins (we hold the fresher evaluation of that
        # spec). The lock serializes read-merge-replace so simultaneous
        # flushes cannot interleave and drop each other's entries.
        with open(self.path.with_suffix(self.path.suffix + ".lock"),
                  "w") as lock:
            try:
                import fcntl
                fcntl.flock(lock, fcntl.LOCK_EX)
            except ImportError:       # non-POSIX: merge without the lock
                pass
            disk = self._read()
            if disk:
                disk.update(self._data)
                self._data = disk
            if (self.max_entries is not None
                    and len(self._data) > self.max_entries):
                # LRU-ish eviction: keep the most recently stamped entries
                keep = sorted(self._data.items(),
                              key=lambda kv: int(kv[1].get("t", 0)),
                              reverse=True)[:self.max_entries]
                self._data = dict(keep)
            fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                       prefix=self.path.name + ".")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(self._data, f)
                os.replace(tmp, self.path)    # atomic publish
                self._dirty = False
                self._touched = 0
            except BaseException:
                os.unlink(tmp)
                raise


# ---------------------------------------------------------------------------
# population evaluation
# ---------------------------------------------------------------------------

# Per-spec packed node tables for the population netlist-sim engine, keyed
# alongside the EvalCache keyspace (EvalCache.key(..., netlist=True) +
# "|pack"): a netlist is a deterministic function of (dataset, seed,
# epochs, spec) in-process, so a GA revisiting a genome whose EvalResult
# was invalidated (or uncached) never re-lays-out its node tables.
# Process-local, LRU-capped (mirroring EvalCache's max_entries): a
# service-style run cycling through many datasets/specs keeps its working
# set and evicts the least-recently-hit tables — entries are a few dense
# KB each, and `netlist_sim.pack_evictions` counts the churn.
_PACK_CACHE: "OrderedDict[str, object]" = OrderedDict()
_PACK_CACHE_CAP = 2048


def _packed_netlist_for(key: Optional[str], net, NS):
    if key is not None and key in _PACK_CACHE:
        MT.counter("netlist_sim.pack_hits").inc()
        _PACK_CACHE.move_to_end(key)
        return _PACK_CACHE[key]
    packed = NS.pack_netlist(net)
    if key is not None:
        while len(_PACK_CACHE) >= _PACK_CACHE_CAP:
            _PACK_CACHE.popitem(last=False)
            MT.counter("netlist_sim.pack_evictions").inc()
        _PACK_CACHE[key] = packed
    return packed


def _compile_and_price(params_pop, specs, masks_serial, xte, yte, *,
                       netlist: bool = True,
                       quarantine: Optional[List[QuarantineRecord]] = None,
                       pack_key: Optional[Callable[[ModelMin], str]] = None
                       ) -> List[MZ.EvalResult]:
    """Host-side bespoke compile per candidate + one vectorized pricing
    call for the whole population. Every candidate is additionally lowered
    to its bespoke netlist (`repro.circuit`) for the critical-path delay;
    with ``netlist=True`` (the default objective) the accuracy is the
    netlist-exact simulation of the printed datapath instead of the float
    emulation (area/power stay on the analytic pricing, which the
    structural netlist cost is tested to reproduce exactly). All exact
    netlist-mode candidates are scored in ONE packed-population launch
    through `repro.kernels.netlist_sim` — per-candidate node tables are
    cached under ``pack_key(spec)`` alongside the EvalCache keyspace, so a
    GA revisiting genomes repacks nothing.

    Candidates carrying approximation genes (`ModelMin.has_approx`) are
    scored by `approx.evaluate_netlist` — the one shared policy with the
    serial path: bit-exact simulation of the *approximated* netlist for
    accuracy, approximation-aware structural pricing for area/power (the
    analytic model cannot see truncated circuits).

    Per-candidate fault isolation: a candidate whose compile/score raises
    (or whose accuracy comes back NaN) is retried once and then quarantined
    with worst-case fitness and a :class:`QuarantineRecord` appended to
    ``quarantine`` — the rest of the population prices and returns
    normally. Retrying matters for transient faults (torn files, flaky
    workers); deterministic failures burn both attempts and quarantine.
    """
    from repro import approx as AX               # lazy: approx imports us
    from repro import circuit as CIRC            # lazy: circuit imports us
    from repro.kernels import netlist_sim as NS  # lazy: imports circuit

    full: Dict[int, MZ.EvalResult] = {}   # approx-scored or quarantined
    compiled: Dict[int, MZ.CompiledMLP] = {}
    nets: Dict[int, object] = {}          # netlist-exact scoring, deferred
    accs: Dict[int, float] = {}
    delays: Dict[int, int] = {}

    for p, spec in enumerate(specs):
        err: Optional[BaseException] = None
        stage = "compile"
        for attempt in (1, 2):
            try:
                if _EVAL_FAULT_HOOK is not None:
                    _EVAL_FAULT_HOOK(spec, attempt)
                stage = "compile"
                params_p = jax.tree_util.tree_map(lambda a, p=p: a[p],
                                                  params_pop)
                c = MZ.compile_bespoke(params_p, spec, masks_serial[p])
                net = CIRC.compile_netlist(c)
                stage = "score"
                if spec.has_approx:
                    r = AX.evaluate_netlist(net, c, spec, xte, yte)
                    if math.isnan(float(r.accuracy)):
                        raise FloatingPointError(
                            "NaN accuracy out of approximated-netlist "
                            "simulation (diverged QAT finetune?)")
                    full[p] = r
                elif netlist:
                    # accuracy deferred: every exact candidate joins ONE
                    # packed-population simulation after this loop (an
                    # integer argmax cannot come back NaN)
                    compiled[p] = c
                    nets[p] = net
                    delays[p] = net.critical_path_levels()
                else:
                    acc = MZ.compiled_accuracy(c, xte, yte)
                    if math.isnan(float(acc)):
                        raise FloatingPointError(
                            "NaN accuracy out of compiled forward "
                            "(diverged QAT finetune?)")
                    compiled[p] = c
                    accs[p] = float(acc)
                    delays[p] = net.critical_path_levels()
                err = None
                break
            except (KeyboardInterrupt, SystemExit):
                raise
            except BaseException as e:
                err = e
        if err is not None:
            rec = QuarantineRecord(spec.to_json(), stage,
                                   type(err).__name__, str(err), attempts=2)
            MT.counter(f"eval.quarantine.{stage}").inc()
            TR.event("eval.quarantine", stage=stage, error=rec.error,
                     message=rec.message, spec=rec.spec_json)
            if quarantine is not None:
                quarantine.append(rec)
            else:
                warnings.warn(f"spec quarantined ({rec.stage}: {rec.error}: "
                              f"{rec.message}); worst-case fitness assigned")
            full[p] = _worst_case_result(spec)

    # one packed-population launch scores every deferred exact candidate;
    # if the batch itself faults, fall back to per-candidate serial
    # simulation under the same retry-once-then-quarantine contract so one
    # poisoned netlist cannot take the generation's scores down with it
    if nets:
        todo_p = sorted(nets)
        try:
            packs = [_packed_netlist_for(
                pack_key(specs[p]) if pack_key else None, nets[p], NS)
                for p in todo_p]
            xq = np.stack([np.asarray(
                MZ.quantize_inputs(compiled[p], xte), np.int64)
                for p in todo_p])
            pop_acc = NS.population_accuracy(NS.pack_population(packs),
                                             xq, yte)
            for j, p in enumerate(todo_p):
                accs[p] = float(pop_acc[j])
        except (KeyboardInterrupt, SystemExit):
            raise
        except BaseException:
            for p in todo_p:
                err2: Optional[BaseException] = None
                for _attempt in (1, 2):
                    try:
                        accs[p] = float(CIRC.netlist_accuracy(
                            nets[p], compiled[p], xte, yte))
                        err2 = None
                        break
                    except (KeyboardInterrupt, SystemExit):
                        raise
                    except BaseException as e:
                        err2 = e
                if err2 is not None:
                    rec = QuarantineRecord(specs[p].to_json(), "score",
                                           type(err2).__name__, str(err2),
                                           attempts=2)
                    MT.counter("eval.quarantine.score").inc()
                    TR.event("eval.quarantine", stage="score",
                             error=rec.error, message=rec.message,
                             spec=rec.spec_json)
                    if quarantine is not None:
                        quarantine.append(rec)
                    else:
                        warnings.warn(
                            f"spec quarantined (score: {rec.error}: "
                            f"{rec.message}); worst-case fitness assigned")
                    full[p] = _worst_case_result(specs[p])
                    del compiled[p]

    # stack per-layer integer weights / codebooks and price the whole
    # population in one hw_model call (pad codebooks to the layer's max k).
    # Only cleanly-compiled exact candidates take part; approx-scored and
    # quarantined ones already carry their full EvalResult.
    ok = sorted(compiled)
    cost = None
    if ok:
        comp = [compiled[p] for p in ok]
        L = len(comp[0].q_layers)
        q_layers, w_bits, clusters = [], [], []
        for i in range(L):
            q_layers.append(np.stack([c.q_layers[i] for c in comp]))
            w_bits.append(np.array([c.w_bits[i] for c in comp], np.int64))
            has = np.array([c.clusters[i] is not None for c in comp])
            if has.any():
                kmax = max(c.clusters[i][1].shape[1]
                           for c in comp if c.clusters[i] is not None)
                d_in, d_out = comp[0].q_layers[i].shape
                idx = np.zeros((len(comp), d_in, d_out), np.int64)
                cb = np.zeros((len(comp), d_in, kmax), np.int64)
                for p, c in enumerate(comp):
                    if c.clusters[i] is not None:
                        ci, cc = c.clusters[i]
                        idx[p] = ci
                        cb[p, :, :cc.shape[1]] = cc
                clusters.append((idx, cb, has))
            else:
                clusters.append(None)
        in_bits = np.array([c.input_bits for c in comp], np.int64)
        cost = HW.mlp_cost_batch(q_layers, w_bits=w_bits, in_bits=in_bits,
                                 clusters=clusters)

    pos = {p: j for j, p in enumerate(ok)}
    return [full[p] if p in full
            else MZ.EvalResult(spec, accs[p],
                               float(cost["area_mm2"][pos[p]]),
                               float(cost["power_mw"][pos[p]]),
                               int(cost["n_multipliers"][pos[p]]),
                               delay_levels=delays[p])
            for p, spec in enumerate(specs)]


def evaluate_population(cfg: PrintedMLPConfig, specs: Sequence[ModelMin], *,
                        epochs: int = 150, seed: int = 0,
                        cache: Optional[EvalCache] = None,
                        netlist: bool = True,
                        quarantine: Optional[List[QuarantineRecord]] = None
                        ) -> List[MZ.EvalResult]:
    """Evaluate a population of specs with ONE vmapped QAT finetune + ONE
    vectorized pricing pass. Order-preserving; duplicates and cache hits
    are evaluated once. Drop-in for `[evaluate_spec(cfg, s) for s in specs]`.

    The accuracy objective defaults to the bit-exact simulation of each
    candidate's compiled netlist (`repro.circuit`) — the printed datapath
    itself, integer biases and all — scored for the whole population in
    one `repro.kernels.netlist_sim` launch and cached under a separate key
    space (old analytic cache entries keep their exact byte keys).
    ``netlist=False`` opts back out to the float emulation
    (`minimize.compiled_accuracy`). Specs with approximation genes are
    always scored
    on their simulated approximated netlist and priced structurally,
    whatever ``netlist`` says; they live in the netlist keyspace (their
    genes are part of the spec JSON, so they can never collide with an
    exact entry).

    A candidate whose compile/score fails is retried once, then quarantined
    with worst-case fitness (never cached, so a fixed toolchain re-evaluates
    it) and a :class:`QuarantineRecord` appended to ``quarantine`` — one
    poisoned genome cannot abort the generation.
    """
    specs = list(specs)
    from repro.verify.diagnostics import verify_enabled
    if specs and verify_enabled():
        # static spec lint before any costly QAT: gene-range/arch
        # legality + serialize->parse->serialize byte-stability (a
        # non-round-tripping spec would fracture the cache keyspace)
        from repro.verify.spec import check_specs
        check_specs(specs, cfg)
    results: Dict[str, MZ.EvalResult] = {}
    todo: List[ModelMin] = []
    queued = set()
    n_hits = 0
    for s in specs:
        k = s.to_json()
        if k in results or k in queued:
            continue
        hit = (cache.get(cfg.name, seed, epochs, s,
                         netlist=netlist or s.has_approx)
               if cache else None)
        n_hits += hit is not None
        if hit is not None and hit.delay_levels is not None:
            # entries from caches predating the circuit compiler carry no
            # delay — fall through and re-evaluate so they upgrade in place
            results[k] = hit
        else:
            todo.append(s)
            queued.add(k)

    MT.counter("eval.specs_requested").inc(len(specs))
    MT.counter("eval.specs_cached").inc(n_hits)
    MT.counter("eval.specs_evaluated").inc(len(todo))
    TR.event("eval.batch", dataset=cfg.name, requested=len(specs),
             hits=n_hits, evaluated=len(todo))

    if todo:
        n_real = len(todo)
        # pad to a power-of-two bucket by repeating the last spec: the jit
        # specializes on the population axis, and a GA's uncached count
        # differs almost every generation — bucketing keeps one compiled
        # executable per bucket instead of a retrace per generation
        bucket = 1 << (n_real - 1).bit_length()
        padded = todo + [todo[-1]] * (bucket - n_real)
        params0, (xtr, ytr, xte, yte) = MZ.pretrain(cfg, seed=seed)
        bits, ks = stack_specs(padded)
        stacked, masks_serial = stack_masks(params0, padded)
        masks = tuple(jnp.asarray(m) for m in stacked)
        # population-bucket padding accounting (same convention as
        # netlist_sim's lane padding): real specs vs padded bucket slots
        MT.counter("eval.pad.specs_real").inc(n_real)
        MT.counter("eval.pad.specs_total").inc(bucket)
        MT.histogram("eval.bucket_util_hist").observe(n_real / bucket)
        bits_j, ks_j = jnp.asarray(bits), jnp.asarray(ks)
        xtr_j, ytr_j = jnp.asarray(xtr), jnp.asarray(ytr)
        args = (params0, bits_j, ks_j, masks, xtr_j, ytr_j)
        kw = dict(epochs=epochs, lr=2e-3)
        # the dispatch wrapper times DISPATCH of the population jit (never
        # runs inside traced code); the first call per (dataset, bucket,
        # epochs) pays XLA compilation, is tagged `first` so reports split
        # compile_ms out, and has its cost/memory analysis captured into
        # the executable registry
        if not TR.active():
            trained = _population_finetune(*args, **kw)
        else:
            TR.event("eval.padding", dataset=cfg.name, specs_real=n_real,
                     specs_total=bucket)
            key = ("finetune", cfg.name, bucket, epochs,
                   tuple(cfg.layer_dims))
            with PF.dispatch("eval.finetune", key,
                             lower=lambda: _population_finetune.lower(
                                 *args, **kw),
                             dataset=cfg.name, bucket=bucket, n=n_real):
                trained = _population_finetune(*args, **kw)
                jax.block_until_ready(trained)
        trained = jax.tree_util.tree_map(
            lambda a: np.asarray(a[:n_real]), trained)
        recs: List[QuarantineRecord] = []

        def pack_key(s: ModelMin) -> str:
            return EvalCache.key(cfg.name, seed, epochs, s,
                                 netlist=True) + "|pack"

        with TR.span("eval.compile_price", dataset=cfg.name, n=n_real):
            priced = _compile_and_price(trained, todo,
                                        masks_serial[:n_real],
                                        xte, yte, netlist=netlist,
                                        quarantine=recs,
                                        pack_key=pack_key)
        for r in priced:
            results[r.spec.to_json()] = r
            if cache is not None and \
                    all(q.spec_json != r.spec.to_json() for q in recs):
                cache.put(cfg.name, seed, epochs, r,
                          netlist=netlist or r.spec.has_approx)
        if recs:
            if quarantine is not None:
                quarantine.extend(recs)
            else:
                warnings.warn(f"{len(recs)} spec(s) quarantined with "
                              "worst-case fitness; pass quarantine=[] to "
                              "collect the structured records")

    # flush on hits too: a get() refreshes the entry's LRU stamp, and a
    # long fully-cached resume must persist that recency or a capped
    # writer would evict exactly the entries this sweep is actively
    # reusing (the cache itself batches recency-only writes, so a warm
    # generation is not a multi-MB rewrite)
    if cache is not None and (todo or n_hits):
        cache.flush()

    return [results[s.to_json()] for s in specs]


def make_batch_evaluator(cfg: PrintedMLPConfig, *, epochs: int = 150,
                         seed: int = 0,
                         cache: Optional[EvalCache] = None,
                         netlist: bool = True,
                         include_delay: bool = False,
                         record: Optional[Dict[str, MZ.EvalResult]] = None,
                         quarantine: Optional[List[QuarantineRecord]]
                         = None):
    """GA adapter: List[ModelMin] -> List[(1 - accuracy, area_mm2[,
    delay_levels])]. Plug into `run_nsga2(..., batch_evaluate=...)`.

    The accuracy objective is netlist-exact by default (the simulated
    printed datapath, batched through `repro.kernels.netlist_sim`);
    ``netlist=False`` opts out to the analytic float emulation.
    ``include_delay=True`` adds the compiled
    circuit's critical path as a third minimized objective. ``record``, if
    given, collects every EvalResult by spec json — callers (fig2, the
    example) read Pareto-front delay out of it without re-evaluating.
    Specs carrying approximation genes are handled per candidate by
    `evaluate_population` (simulated approximate netlist + structural
    pricing) whatever ``netlist`` says. ``quarantine``, if given, collects
    the `QuarantineRecord`s of failing specs — share the list with
    `run_nsga2(quarantine=...)` / the island runtime so quarantined specs
    surface on the final result.
    """
    def batch_evaluate(specs: Sequence[ModelMin]):
        rs = evaluate_population(cfg, specs, epochs=epochs, seed=seed,
                                 cache=cache, netlist=netlist,
                                 quarantine=quarantine)
        if record is not None:
            record.update((r.spec.to_json(), r) for r in rs)
        if include_delay:
            return [(1.0 - r.accuracy, r.area_mm2, float(r.delay_levels))
                    for r in rs]
        return [(1.0 - r.accuracy, r.area_mm2) for r in rs]
    return batch_evaluate
