"""Printed bespoke MLP classifiers (the paper's model family).

Mubarik et al. (MICRO'20) printed MLPs are tiny fully-connected nets
(one hidden layer, ReLU, hardwired coefficients). We keep them as plain
dense stacks; ``repro.core`` compresses their weight pytrees and
``repro.core.hw_model`` prices them as bespoke printed circuits.
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


def mlp_init(key, layer_dims: Sequence[int], dtype=jnp.float32):
    """layer_dims: (in, hidden..., out). Returns {"layers": ({"w","b"}, ...)}."""
    params = []
    ks = jax.random.split(key, len(layer_dims) - 1)
    for k, d_in, d_out in zip(ks, layer_dims[:-1], layer_dims[1:]):
        w = jax.random.uniform(k, (d_in, d_out), jnp.float32,
                               -1.0, 1.0) * math.sqrt(6.0 / (d_in + d_out))
        params.append({"w": w.astype(dtype), "b": jnp.zeros((d_out,), dtype)})
    return {"layers": tuple(params)}


def mlp_forward(params, x):
    """x: (B, F) -> logits (B, C). ReLU hidden activations (printed-friendly:
    ReLU is a comparator+mux in bespoke logic)."""
    hs = params["layers"]
    for i, layer in enumerate(hs):
        x = x @ layer["w"] + layer["b"]
        if i < len(hs) - 1:
            x = jax.nn.relu(x)
    return x


def accuracy(params, x, y) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(mlp_forward(params, x), -1) == y)
                    .astype(jnp.float32))


def layer_dims(params) -> Tuple[Tuple[int, int], ...]:
    return tuple(tuple(l["w"].shape) for l in params["layers"])
