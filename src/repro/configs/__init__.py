from repro.configs.base import (ArchConfig, LayerSpec, Segment, ShapeConfig,
                                SHAPES, shape_applicable)
from repro.configs.registry import ARCHS, ARCH_IDS, get_arch

__all__ = ["ArchConfig", "LayerSpec", "Segment", "ShapeConfig", "SHAPES",
           "shape_applicable", "ARCHS", "ARCH_IDS", "get_arch"]
