"""Unit tests for `ckpt.CheckpointManager` — the primitive the search
runtime's checkpoint/resume is built on (previously only exercised
indirectly)."""
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager


def _tree():
    return {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": np.array([1, 2, 3], dtype=np.int64)}


def _assert_tree_equal(a, b):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_save_restore_roundtrip_sync(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    tree = _tree()
    mgr.save(7, tree, meta={"round": 7, "note": "hello"})
    like = {k: 0 for k in tree}
    restored, meta = mgr.restore(like=like)
    _assert_tree_equal(restored, tree)
    assert meta == {"round": 7, "note": "hello"}
    assert mgr.latest_step() == 7


def test_save_restore_roundtrip_async(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=True)
    tree = _tree()
    mgr.save(1, tree, meta={"k": 1}, block=True)
    mgr.wait()
    restored, meta = mgr.restore(like={k: 0 for k in tree})
    _assert_tree_equal(restored, tree)
    assert meta == {"k": 1}


def test_atomic_tmp_rename(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    # a stale .tmp from a previous crashed writer must not break the save,
    # must never be listed as a step, and must be gone after the publish
    stale = tmp_path / "step_00000003.tmp"
    stale.mkdir()
    (stale / "garbage").write_text("torn write")
    assert mgr.all_steps() == []              # .tmp dirs are not steps
    mgr.save(3, _tree())
    assert mgr.all_steps() == [3]
    assert not stale.exists()                 # renamed over, not leaked
    assert not list(tmp_path.glob("*.tmp"))
    restored, _ = mgr.restore(3, like={"w": 0, "b": 0})
    _assert_tree_equal(restored, _tree())


def test_keep_n_pruning(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    for s in range(5):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]
    # latest restore still works after pruning
    restored, _ = mgr.restore(like={"w": 0, "b": 0})
    _assert_tree_equal(restored, _tree())


def test_async_writer_error_propagates_into_next_save(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=True)
    # a set is not JSON-serializable: the manifest dump fails on the
    # writer thread, and the failure must surface on the NEXT save()
    mgr.save(0, _tree(), meta={"bad": {1, 2, 3}})
    mgr._q.join()                             # let the writer hit the error
    with pytest.raises(TypeError):
        mgr.save(1, _tree())
    # the error is cleared once raised: subsequent saves work again
    mgr.save(2, _tree(), block=True)
    mgr.wait()
    assert 2 in mgr.all_steps()


def test_restore_empty_root_returns_none(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    tree, meta = mgr.restore()
    assert tree is None and meta is None
