"""Paper Fig. 1: accuracy–area Pareto fronts of the three STANDALONE
minimization techniques on the four classifiers, normalized to the
un-minimized 8-bit bespoke baseline (Mubarik MICRO'20).

Paper claims to validate (≤5% absolute accuracy loss):
  quantization ~5x mean area gain; pruning ~2.8x; clustering ~3.5x
  (clustering meets the 5% bound only on the wine datasets).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import numpy as np

from repro.configs.printed_mlp import PRINTED_MLPS
from repro.core import minimize as MZ
from repro.core.pareto import gain_at_loss, pareto_front


def run(fast: bool = False) -> Dict:
    epochs = 60 if fast else 150
    datasets = ["seeds"] if fast else list(PRINTED_MLPS)
    out: Dict[str, Dict] = {}
    for name in datasets:
        cfg = PRINTED_MLPS[name]
        base = MZ.baseline(cfg)
        rows = {}
        sweeps = {
            "quantization": MZ.quant_sweep(cfg, range(2, 8), epochs=epochs),
            "pruning": MZ.prune_sweep(cfg, (0.2, 0.3, 0.4, 0.5, 0.6),
                                      epochs=epochs),
            "clustering": MZ.cluster_sweep(cfg, (2, 3, 4, 6, 8),
                                           epochs=epochs),
        }
        for tech, results in sweeps.items():
            pts = [(r.accuracy, r.area_mm2) for r in results]
            gain = gain_at_loss(pts, baseline_acc=base.accuracy,
                                baseline_area=base.area_mm2, max_loss=0.05)
            rows[tech] = {
                "points": [(round(a, 4), round(ar, 1)) for a, ar in pts],
                "gain_at_5pct": round(gain, 2),
            }
        out[name] = {
            "baseline_acc": round(base.accuracy, 4),
            "baseline_area_mm2": round(base.area_mm2, 1),
            "techniques": rows,
        }
    return out


def main(fast: bool = False):
    t0 = time.time()
    res = run(fast=fast)
    print("fig1_standalone (area gains at <=5% accuracy loss, "
          "normalized to 8-bit bespoke baseline)")
    print(f"{'dataset':12s} {'base_acc':>8s} {'base_cm2':>9s} "
          f"{'quant':>6s} {'prune':>6s} {'clust':>6s}")
    means = {"quantization": [], "pruning": [], "clustering": []}
    for name, r in res.items():
        t = r["techniques"]
        for k in means:
            means[k].append(t[k]["gain_at_5pct"])
        print(f"{name:12s} {r['baseline_acc']:8.3f} "
              f"{r['baseline_area_mm2']/100:9.1f} "
              f"{t['quantization']['gain_at_5pct']:6.2f} "
              f"{t['pruning']['gain_at_5pct']:6.2f} "
              f"{t['clustering']['gain_at_5pct']:6.2f}")
    print(f"{'MEAN':12s} {'':8s} {'':9s} "
          + " ".join(f"{np.mean(means[k]):6.2f}"
                     for k in ("quantization", "pruning", "clustering")))
    print(f"paper:       quant ~5x | prune ~2.8x | cluster ~3.5x "
          f"[{time.time()-t0:.0f}s]")
    return res


if __name__ == "__main__":
    main()
