"""Greedy budgeted application of the approximation passes.

`ApproxParams` is the full knob vector (per-layer CSD digit drops, per-layer
accumulator LSB truncations, argmax comparator truncation) — the same genes
`compression_spec` carries for the GA. `fit_budget` raises knobs one step
at a time, re-running the pass pipeline from the exact netlist and keeping
a step only while the interval analyzer's worst-case decision-error bound
stays within the user's logit-error budget — so the returned circuit comes
with a *proof* of its maximum logit deviation, not just a measured one.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.circuit import cost as COST
from repro.circuit import ir
from repro.approx.analyze import decision_error_bound, logit_error_bound
from repro.approx.passes import RoundCoeffsCSD, SimplifyActs, TruncateAccum
from repro.approx.rewrite import PassManager


@dataclasses.dataclass(frozen=True)
class ApproxParams:
    """Per-layer approximation knobs; all-zero is the identity."""
    csd_drop: Tuple[int, ...]
    lsb: Tuple[int, ...]
    argmax_lsb: int = 0

    @staticmethod
    def zero(n_layers: int) -> "ApproxParams":
        return ApproxParams((0,) * n_layers, (0,) * n_layers, 0)

    @staticmethod
    def from_spec(spec) -> "ApproxParams":
        """Lift the approximation genes out of a `ModelMin`."""
        return ApproxParams(tuple(l.csd_drop for l in spec.layers),
                            tuple(l.lsb for l in spec.layers),
                            spec.argmax_lsb)

    @property
    def is_identity(self) -> bool:
        return (not any(self.csd_drop) and not any(self.lsb)
                and self.argmax_lsb == 0)


def build_passes(p: ApproxParams) -> List:
    """Pass pipeline for a knob vector. Coefficient rounding runs first
    (it rebuilds the multiplier subnets), LSB truncation wraps the rebuilt
    roots, activation/comparator simplification runs last. The all-zero
    vector yields an empty (identity) pipeline — any *approximated*
    candidate gets SimplifyActs, so its exact ReLU elision (which fires
    only where provably error-free) applies uniformly rather than riding
    on the argmax knob alone."""
    out = []
    if any(p.csd_drop):
        out.append(RoundCoeffsCSD(p.csd_drop))
    if any(p.lsb):
        out.append(TruncateAccum(p.lsb))
    if not p.is_identity:
        out.append(SimplifyActs(p.argmax_lsb))
    return out


def approximate(net: ir.Netlist, p: ApproxParams) -> ir.Netlist:
    """Apply the knob vector to an exact netlist. Identity knobs still run
    the (empty) PassManager — bit-exact, cost-exact (tested)."""
    return PassManager(build_passes(p)).run(net)


def evaluate_netlist(net: ir.Netlist, compiled, spec, xte, yte):
    """THE scoring policy for a candidate carrying approximation genes,
    shared by the serial (`minimize.evaluate_spec`) and batched
    (`batch_eval._compile_and_price`) paths so they can never drift: the
    printed circuit is the approximated netlist, so accuracy is its
    bit-exact simulation, area/power the approximation-aware structural
    pricing, delay its critical path. ``net`` is the candidate's EXACT
    compiled netlist. Returns a `minimize.EvalResult`."""
    from repro.circuit.simulate import netlist_accuracy
    from repro.core import minimize as MZ       # lazy: minimize imports us

    anet = approximate(net, ApproxParams.from_spec(spec))
    sc = COST.structural_cost(anet)
    return MZ.EvalResult(spec, netlist_accuracy(anet, compiled, xte, yte),
                         sc.area_mm2, sc.power_mw, sc.n_multipliers,
                         delay_levels=anet.critical_path_levels())


def logit_budget(net: ir.Netlist, frac: float) -> int:
    """Absolute logit-error budget as a fraction of the circuit's largest
    worst-case logit magnitude — a scale-free way to say 'x% of the logit
    range' across datasets and specs."""
    mag = max((max(abs(net.nodes[i].lo), abs(net.nodes[i].hi))
               for i in net.output_ids), default=0)
    return max(int(frac * mag), 0)


@dataclasses.dataclass
class BudgetReport:
    params: ApproxParams
    budget: int
    bound: int                 # analyzer's decision-error bound at params
    logit_bound: int           # bound on the logit nodes themselves
    exact_fa: float
    approx_fa: float
    steps: List[Tuple[str, int]]   # accepted (knob, new value) sequence

    @property
    def area_gain(self) -> float:
        return self.exact_fa / max(self.approx_fa, 1e-9)


def fit_budget(net: ir.Netlist, budget: int, *,
               max_csd_drop: int = 6, max_lsb: int = 10,
               max_argmax_lsb: int = 8
               ) -> Tuple[ApproxParams, ir.Netlist, BudgetReport]:
    """Greedily raise approximation knobs under a worst-case logit-error
    budget (integer logit LSBs, see `logit_budget`). Each round tries a
    one-step raise of every knob (re-running the pipeline from the exact
    netlist — passes compose but error bounds do not decompose, so the
    analyzer must see the whole pipeline); a raise is kept iff the
    decision-error bound stays within budget. Terminates when no knob can
    be raised. Returns (params, approximated netlist, report)."""
    L = net.n_layers
    knobs = ([("csd", i, max_csd_drop) for i in range(L)]
             + [("lsb", i, max_lsb) for i in range(L)]
             + [("argmax", -1, max_argmax_lsb)])
    params = ApproxParams.zero(L)
    exact_fa = COST.structural_cost(net).total_fa
    steps: List[Tuple[str, int]] = []
    best_net: Optional[ir.Netlist] = None

    def bump(p: ApproxParams, kind: str, i: int) -> ApproxParams:
        if kind == "csd":
            v = list(p.csd_drop)
            v[i] += 1
            return dataclasses.replace(p, csd_drop=tuple(v))
        if kind == "lsb":
            v = list(p.lsb)
            v[i] += 1
            return dataclasses.replace(p, lsb=tuple(v))
        return dataclasses.replace(p, argmax_lsb=p.argmax_lsb + 1)

    def level(p: ApproxParams, kind: str, i: int) -> int:
        return (p.csd_drop[i] if kind == "csd"
                else p.lsb[i] if kind == "lsb" else p.argmax_lsb)

    cur_fa = exact_fa
    improved = True
    while improved:
        improved = False
        for kind, i, cap in knobs:
            if level(params, kind, i) >= cap:
                continue
            trial = bump(params, kind, i)
            anet = approximate(net, trial)
            fa = COST.structural_cost(anet).total_fa
            # a bump must actually shrink the circuit: saturated knobs
            # (all CSD digits already dropped, truncation clamped at the
            # word width) rewrite nothing and would otherwise inflate to
            # their caps, overstating the applied approximation
            if fa < cur_fa and decision_error_bound(anet) <= budget:
                params, best_net, improved = trial, anet, True
                cur_fa = fa
                steps.append((f"{kind}[{i}]" if i >= 0 else kind,
                              level(trial, kind, i)))

    if best_net is None:
        best_net = approximate(net, params)
    report = BudgetReport(
        params=params, budget=budget,
        bound=decision_error_bound(best_net),
        logit_bound=logit_error_bound(best_net),
        exact_fa=exact_fa,
        approx_fa=COST.structural_cost(best_net).total_fa,
        steps=steps)
    from repro.verify.diagnostics import verify_enabled
    if verify_enabled():
        # fit_budget's output contract: a verifier-clean, DCE-compacted
        # netlist whose proven decision-error bound honors the budget
        from repro.verify.diagnostics import (ERROR, Diagnostic,
                                              VerificationError)
        from repro.verify.netlist import check_netlist
        check_netlist(best_net, strict=True, expect_dce=True)
        if report.bound > budget >= 0:
            raise VerificationError([Diagnostic(
                ERROR, "budget",
                f"fit_budget returned bound {report.bound} over the "
                f"requested budget {budget}")])
    return params, best_net, report
