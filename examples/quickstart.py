"""Quickstart: the paper's pipeline end to end in ~a minute.

Trains the Seeds printed-MLP classifier, applies each minimization technique
standalone, prices every design with the bespoke printed-circuit area model,
and prints the accuracy/area trade-off against the un-minimized baseline.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.printed_mlp import SEEDS
from repro.core import minimize as MZ
from repro.core.compression_spec import ModelMin

n_layers = len(SEEDS.layer_dims) - 1

print("1. un-minimized 8-bit bespoke baseline (Mubarik MICRO'20)")
base = MZ.baseline(SEEDS)
print(f"   acc={base.accuracy:.3f} area={base.area_mm2/100:.1f} cm^2 "
      f"power={base.power_mw:.1f} mW mults={base.n_multipliers}")

print("2. quantization to 4 bits (QAT)")
r = MZ.evaluate_spec(SEEDS, ModelMin.uniform(n_layers, bits=4))
print(f"   acc={r.accuracy:.3f} area={r.area_mm2/100:.1f} cm^2 "
      f"-> {base.area_mm2/r.area_mm2:.2f}x smaller")

print("3. unstructured pruning to 50% sparsity")
r = MZ.evaluate_spec(SEEDS, ModelMin.uniform(n_layers, bits=8, sparsity=0.5))
print(f"   acc={r.accuracy:.3f} area={r.area_mm2/100:.1f} cm^2 "
      f"-> {base.area_mm2/r.area_mm2:.2f}x smaller")

print("4. per-input weight clustering, k=4 (shared multipliers)")
r = MZ.evaluate_spec(SEEDS, ModelMin.uniform(n_layers, bits=8, clusters=4))
print(f"   acc={r.accuracy:.3f} area={r.area_mm2/100:.1f} cm^2 "
      f"-> {base.area_mm2/r.area_mm2:.2f}x smaller, "
      f"mults={r.n_multipliers} (vs {base.n_multipliers})")

print("5. all three combined (one GA candidate)")
r = MZ.evaluate_spec(SEEDS, ModelMin.uniform(n_layers, bits=4, sparsity=0.3,
                                             clusters=6))
print(f"   acc={r.accuracy:.3f} area={r.area_mm2/100:.1f} cm^2 "
      f"-> {base.area_mm2/r.area_mm2:.2f}x smaller")
print("done. benchmarks/fig2_combined.py runs the full hardware-aware GA.")
