"""Bit-exact batched netlist simulation in JAX.

The netlist is static per compiled model, so all scheduling happens once on
the host: nodes are grouped into topological levels and, within each level,
by opcode. The resulting plan is a short list of gather -> elementwise-op ->
scatter steps over one flat value buffer; the evaluator is a single jitted
function, ``vmap``-ed over the input batch. Every intermediate is an exact
machine integer — int32 when the verifier's per-node width bounds say every
datapath word fits a 32-bit lane (`repro.verify.netlist.fits_int32`; the
bound is inclusive at width 32, i.e. exactly the int32 range), int64 (under
a local ``enable_x64`` scope) otherwise — so the simulation reproduces
`minimize.integer_forward` bit-for-bit; there is no float anywhere in the
datapath.

For *population* throughput (the GA's netlist-exact objective) use
`repro.kernels.netlist_sim`: this module rebuilds a jitted executable per
netlist, which is exactly the per-candidate compile cost the packed
population engine exists to amortize. `netlist_accuracy` below already
routes through it.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.circuit import ir


@dataclasses.dataclass(frozen=True)
class _Step:
    """One level-batched op group: out[i] = op(a[i] [, b[i] | shift[i]])."""
    op: ir.Op
    out: np.ndarray                   # node ids to write
    a: np.ndarray                     # first-arg node ids
    b: np.ndarray                     # second-arg ids (ADD/SUB) or shifts


@dataclasses.dataclass(frozen=True)
class SimPlan:
    n_nodes: int
    const_ids: np.ndarray
    const_vals: np.ndarray
    input_ids: np.ndarray
    steps: Tuple[_Step, ...]
    pre_ids: Tuple[np.ndarray, ...]   # per-layer integer pre-activations
    output_ids: np.ndarray
    # the ARGMAX node's actual operands: equal to output_ids on exact
    # netlists, but approximation passes may interpose comparator-input
    # TRUNC nodes — the decision must be taken over what the printed
    # comparator tree actually sees
    argmax_ids: np.ndarray
    max_width: int


def build_plan(net: ir.Netlist) -> SimPlan:
    """Schedule the netlist: per topological level, per opcode, one step."""
    steps: List[_Step] = []
    consts: List[Tuple[int, int]] = []
    for level in net.levels():
        by_op: Dict[ir.Op, List[int]] = {}
        for nid in level:
            n = net.nodes[nid]
            if n.op == ir.Op.CONST:
                consts.append((nid, n.value))
            elif n.op in (ir.Op.INPUT, ir.Op.ARGMAX):
                continue              # inputs seeded, argmax done at the end
            else:
                by_op.setdefault(n.op, []).append(nid)
        for op, ids in sorted(by_op.items()):
            nodes = [net.nodes[i] for i in ids]
            a = np.array([n.args[0] for n in nodes], np.int32)
            if op in (ir.Op.SHL, ir.Op.TRUNC):
                b = np.array([n.shift for n in nodes], np.int32)
            elif op in (ir.Op.ADD, ir.Op.SUB):
                b = np.array([n.args[1] for n in nodes], np.int32)
            else:                     # NEG / RELU: unary
                b = np.zeros(len(nodes), np.int32)
            steps.append(_Step(op, np.array(ids, np.int32), a, b))
    cid = np.array([c[0] for c in consts], np.int32)
    cval = np.array([c[1] for c in consts], np.int64)
    am = (net.nodes[net.argmax_id].args if net.argmax_id is not None
          else net.output_ids)
    return SimPlan(
        n_nodes=len(net), const_ids=cid, const_vals=cval,
        input_ids=np.array(net.input_ids, np.int32),
        steps=tuple(steps),
        pre_ids=tuple(np.array(p, np.int32) for p in net.layer_pre_ids),
        output_ids=np.array(net.output_ids, np.int32),
        argmax_ids=np.array(am, np.int32),
        max_width=net.max_width)


def _evaluate(plan: SimPlan, x: jnp.ndarray, dtype
              ) -> Tuple[List[jnp.ndarray], jnp.ndarray]:
    """One sample through the plan. x: (n_inputs,) int. Returns (per-layer
    pre-activation vectors, the argmax comparator's operand vector) — the
    dataflow is pure integer throughout."""
    vals = jnp.zeros(plan.n_nodes, dtype)
    vals = vals.at[plan.const_ids].set(plan.const_vals.astype(dtype))
    vals = vals.at[plan.input_ids].set(x.astype(dtype))
    for s in plan.steps:
        a = vals[s.a]
        if s.op == ir.Op.SHL:
            r = jnp.left_shift(a, s.b.astype(dtype))
        elif s.op == ir.Op.TRUNC:
            # arithmetic shift right then left: floor-truncate the low bits
            k = s.b.astype(dtype)
            r = jnp.left_shift(jnp.right_shift(a, k), k)
        elif s.op == ir.Op.ADD:
            r = a + vals[s.b]
        elif s.op == ir.Op.SUB:
            r = a - vals[s.b]
        elif s.op == ir.Op.NEG:
            r = -a
        else:                         # RELU
            r = jnp.maximum(a, 0)
        vals = vals.at[s.out].set(r)
    return [vals[p] for p in plan.pre_ids], vals[plan.argmax_ids]


class Simulator:
    """Compiled batched evaluator for one netlist.

    ``run(x_int)`` -> dict with per-layer integer ``pre`` activations,
    integer ``logits`` and the ``argmax`` class — all exact. The jitted
    executable is built once and reused across calls; int64 netlists are
    traced and executed inside a local x64 scope (the repo default stays
    32-bit everywhere else).
    """

    def __init__(self, net: ir.Netlist):
        # lazy: repro.verify imports repro.circuit for the IR types
        from repro.verify.netlist import fits_int32
        self.plan = build_plan(net)
        # per-node width bounds, inclusive at 32: a width-32 word is
        # exactly the int32 range, and the old whole-net `max_width > 31`
        # check promoted such nets to 64-bit lanes they never needed
        self._x64 = not fits_int32(net)
        dtype = jnp.int64 if self._x64 else jnp.int32

        def batch(x):                 # x: (B, n_inputs)
            pres, amx = jax.vmap(
                lambda row: _evaluate(self.plan, row, dtype))(x)
            # decide over what the comparator tree actually sees (its
            # inputs may be truncated by the approximation passes)
            return pres, jnp.argmax(amx, axis=-1)

        with self._scope():
            self._fn = jax.jit(batch)

    def _scope(self):
        return enable_x64() if self._x64 else contextlib.nullcontext()

    def run(self, x_int: np.ndarray) -> Dict[str, np.ndarray]:
        x = np.asarray(x_int)
        squeeze = x.ndim == 1
        if squeeze:
            x = x[None]
        with self._scope():
            pres, cls = self._fn(jnp.asarray(x))
            pres = [np.asarray(p, np.int64) for p in pres]
            cls = np.asarray(cls)
        if squeeze:
            pres, cls = [p[0] for p in pres], cls[0]
        return {"pre": pres, "logits": pres[-1], "argmax": cls}


def simulate(net: ir.Netlist, x_int: np.ndarray) -> Dict[str, np.ndarray]:
    """One-shot helper (builds a fresh Simulator; reuse Simulator for
    repeated batches)."""
    return Simulator(net).run(x_int)


def netlist_accuracy(net: ir.Netlist, c, x: np.ndarray,
                     y: np.ndarray) -> float:
    """Netlist-exact test accuracy: ADC-quantize features with the QAT
    compile's rounding, evaluate the printed datapath, compare argmax.

    Routed through the packed population engine
    (`repro.kernels.netlist_sim`) with P=1: its executables specialize on
    bucketed shapes shared across a dataset's candidates, so repeated
    serial scoring (the approx budget search, `evaluate_spec`) stops
    paying a per-netlist XLA trace+compile. Bit-exact vs `Simulator.run`
    by the kernel's tested contract."""
    from repro.core import minimize as MZ
    from repro.kernels.netlist_sim import pack_population, population_accuracy
    xq = MZ.quantize_inputs(c, x)
    acc = population_accuracy(pack_population([net]), np.asarray(xq),
                              np.asarray(y))
    return float(acc[0])
