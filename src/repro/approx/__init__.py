"""Netlist approximation subsystem: pass-based circuit transforms with
interval worst-case error bounds, searched by the GA.

Built on the PR 3 circuit IR (`repro.circuit`): passes rebuild the netlist
(widths/levels re-derived by construction), the analyzer turns local
rewrite annotations + TRUNC semantics into per-logit worst-case error
bounds, and `circuit.cost.structural_cost` prices the approximated circuit
(TRUNC-aware width discounts) where the analytic `hw_model` cannot.

* `repro.approx.rewrite`  — rebuild walk, Pass / PassManager, DCE
* `repro.approx.passes`   — RoundCoeffsCSD / TruncateAccum / SimplifyActs
* `repro.approx.analyze`  — interval error propagation + logit bounds
                            (pure Python ints — jaxlint-enforced)
* `repro.approx.measure`  — simulation-measured counterparts of the bounds
* `repro.approx.budget`   — ApproxParams, greedy `fit_budget` under a
                            user-supplied logit-error budget

Quick use::

    net, compiled = circuit.compile_spec(cfg, spec, epochs=60)
    budget = approx.logit_budget(net, 0.01)          # 1% of logit range
    params, anet, rep = approx.fit_budget(net, budget)
    acc = circuit.netlist_accuracy(anet, compiled, xte, yte)
    print(rep.area_gain, rep.bound)                  # proven error ceiling

The GA searches the same knobs as genes: `LayerMin.csd_drop` / `.lsb` and
`ModelMin.argmax_lsb` (see `core.ga` / `core.batch_eval`).
"""
from repro.approx import analyze, budget, measure, passes, rewrite  # noqa: F401
from repro.approx.analyze import (decision_error_bound,  # noqa: F401
                                  logit_error_bound,
                                  propagate_errors)
from repro.approx.measure import measured_max_logit_error  # noqa: F401
from repro.approx.budget import (ApproxParams, BudgetReport,  # noqa: F401
                                 approximate, build_passes,
                                 evaluate_netlist, fit_budget, logit_budget)
from repro.approx.passes import (RoundCoeffsCSD, SimplifyActs,  # noqa: F401
                                 TruncateAccum, product_info, truncate_csd)
from repro.approx.rewrite import Pass, PassManager, rebuild  # noqa: F401
