"""Weight clustering (paper §II-C, mechanism of Han et al. Deep Compression).

Two granularities:

* ``kmeans_layer`` — classic per-layer k-means codebook (Deep Compression).
* ``cluster_per_input`` — the paper's *hardware* form: weights in the same
  input row (i.e. multiplied by the same input x_i) are forced to shared
  values, so the bespoke circuit computes each product x_i * c once and fans
  it out. The number of *multipliers* for input i collapses from fan-out to
  (#distinct clusters in row i).

Both return (codebook, indices) plus helpers to reconstruct weights, an STE
reconstruction for cluster-aware retraining, and multiplier statistics
consumed by the printed-area model.

TPU adaptation: per-tile codebooks (``kernels/clustered_matmul``) — the
shareable unit on TPU is an HBM->VMEM transfer, not a product wire.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# 1-D k-means (weights are scalars -> exact-ish via sorted init + Lloyd)
# ---------------------------------------------------------------------------


def _kmeans_1d(x: jnp.ndarray, k: int, iters: int = 25):
    """x: (N,) fp32. Returns (centroids (k,), assign (N,) int32).
    Deterministic: quantile init + Lloyd iterations (jit-friendly).

    Deliberately NOT jitted: this is the eager numerical reference that
    `batch_eval._padded_kmeans_1d` is tested bit-exact against (a fused
    standalone executable rounds differently by ~1 ulp). The hot eager
    entry is `cluster_per_input`, which owns the jit boundary."""
    qs = (jnp.arange(k, dtype=jnp.float32) + 0.5) / k
    cent = jnp.quantile(x, qs)

    def step(cent, _):
        d = jnp.abs(x[:, None] - cent[None, :])            # (N,k)
        a = jnp.argmin(d, axis=1)
        one = jax.nn.one_hot(a, k, dtype=jnp.float32)       # (N,k)
        cnt = one.sum(0)
        s = (one * x[:, None]).sum(0)
        new = jnp.where(cnt > 0, s / jnp.maximum(cnt, 1), cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    a = jnp.argmin(jnp.abs(x[:, None] - cent[None, :]), axis=1)
    return cent, a.astype(jnp.int32)


def kmeans_layer(w: jnp.ndarray, k: int, iters: int = 25):
    """Per-layer codebook. Returns (codebook (k,), idx w.shape int32)."""
    flat = w.astype(jnp.float32).reshape(-1)
    cent, a = _kmeans_1d(flat, k, iters)
    return cent, a.reshape(w.shape)


@partial(jax.jit, static_argnames=("k", "iters"))
def cluster_per_input(w: jnp.ndarray, k: int, iters: int = 25):
    """Paper's multiplier-sharing form: k-means per input row.
    w: (d_in, d_out). Returns (codebooks (d_in, k), idx (d_in, d_out)).

    Jitted with static (k, iters): called eagerly per candidate layer from
    ``minimize.compile_bespoke``, an un-jitted entry would retrace the Lloyd
    ``lax.scan`` on EVERY call and re-enter the backend compiler each warm
    GA generation (found by the executable observatory — the netlist_bench
    zero-compile gate attributed ~14 backend compiles per generation to
    this site). Static k/iters keep one executable per (shape, k)."""
    f = jax.vmap(lambda row: _kmeans_1d(row, k=k, iters=iters))
    cent, a = f(w.astype(jnp.float32))
    return cent, a


def reconstruct_layer(codebook: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(codebook, idx)


def reconstruct_per_input(codebooks: jnp.ndarray, idx: jnp.ndarray):
    """codebooks (d_in, k), idx (d_in, d_out) -> w (d_in, d_out)."""
    return jnp.take_along_axis(codebooks, idx, axis=1)


def cluster_ste(w: jnp.ndarray, k: int, *, per_input: bool = True):
    """Cluster-aware training forward: snap to current codebook, identity
    gradient (Deep Compression fine-tunes the shared values; STE over the
    assignment is the standard relaxation)."""
    wd = jax.lax.stop_gradient(w)
    if per_input and w.ndim == 2:
        cb, idx = cluster_per_input(wd, k)
        wq = reconstruct_per_input(cb, idx)
    else:
        cb, idx = kmeans_layer(wd, k)
        wq = reconstruct_layer(cb, idx)
    return w + (wq.astype(w.dtype) - jax.lax.stop_gradient(w))


# ---------------------------------------------------------------------------
# hardware statistics
# ---------------------------------------------------------------------------


def multipliers_needed(idx: jnp.ndarray, codebooks: jnp.ndarray) -> int:
    """Bespoke multiplier count after per-input sharing: for each input row,
    one multiplier per *distinct, non-zero* cluster actually used."""
    d_in, k = codebooks.shape
    used = jax.vmap(lambda row: jax.nn.one_hot(row, k).max(0))(idx)  # (d_in,k)
    nonzero = jnp.abs(codebooks) > 1e-8
    return int(jnp.sum(used * nonzero))


def clustering_error(w: jnp.ndarray, k: int, *, per_input: bool = True) -> float:
    if per_input and w.ndim == 2:
        cb, idx = cluster_per_input(w, k)
        wq = reconstruct_per_input(cb, idx)
    else:
        cb, idx = kmeans_layer(w, k)
        wq = reconstruct_layer(cb, idx)
    return float(jnp.linalg.norm(w - wq) /
                 jnp.maximum(jnp.linalg.norm(w), 1e-9))
