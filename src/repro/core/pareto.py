"""Pareto utilities: non-dominated sorting, crowding, hypervolume, and the
paper's headline metric — area gain at a bounded accuracy loss."""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """Minimization in every objective."""
    a, b = np.asarray(a), np.asarray(b)
    return bool(np.all(a <= b) and np.any(a < b))


def non_dominated_sort(points: np.ndarray) -> List[np.ndarray]:
    """points (N, M), minimization. Returns list of index arrays per front."""
    n = len(points)
    S = [[] for _ in range(n)]
    counts = np.zeros(n, int)
    fronts: List[List[int]] = [[]]
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            if dominates(points[i], points[j]):
                S[i].append(j)
            elif dominates(points[j], points[i]):
                counts[i] += 1
        if counts[i] == 0:
            fronts[0].append(i)
    k = 0
    while fronts[k]:
        nxt = []
        for i in fronts[k]:
            for j in S[i]:
                counts[j] -= 1
                if counts[j] == 0:
                    nxt.append(j)
        k += 1
        fronts.append(nxt)
    return [np.asarray(f, int) for f in fronts[:-1]]


def crowding_distance(points: np.ndarray) -> np.ndarray:
    n, m = points.shape
    d = np.zeros(n)
    if n <= 2:
        return np.full(n, np.inf)
    for k in range(m):
        order = np.argsort(points[:, k])
        d[order[0]] = d[order[-1]] = np.inf
        rng = points[order[-1], k] - points[order[0], k]
        if rng <= 0:
            continue
        d[order[1:-1]] += (points[order[2:], k] - points[order[:-2], k]) / rng
    return d


def first_front_mask(points: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated points (minimization). Vectorized —
    three array ops on an (N, N, M) broadcast instead of the per-pair
    python loop, so it is the right primitive for hot paths (per-generation
    trace stats, the final front over every memoized evaluation)."""
    pts = np.asarray(points, float)
    a, b = pts[:, None, :], pts[None, :, :]
    dominated = ((b <= a).all(-1) & (b < a).any(-1)).any(1)
    return ~dominated


def pareto_front(points: np.ndarray) -> np.ndarray:
    """Indices of the first front — same set and order as
    ``non_dominated_sort(points)[0]``."""
    return np.flatnonzero(first_front_mask(points))


def hypervolume_2d(points: np.ndarray, ref: Tuple[float, float]) -> float:
    """2-objective minimization hypervolume w.r.t. ref point."""
    pts = np.asarray(points, float)
    front = pts[pareto_front(pts)]
    front = front[np.argsort(front[:, 0])]
    hv, prev_y = 0.0, ref[1]
    for x, y in front:
        if x >= ref[0] or y >= prev_y:
            continue
        hv += (ref[0] - x) * (prev_y - y)
        prev_y = y
    return hv


def gain_at_loss(points, *, baseline_acc: float, baseline_area: float,
                 max_loss: float = 0.05) -> float:
    """Paper metric: max area reduction factor among designs within
    ``max_loss`` absolute accuracy drop of the baseline. points: (acc, area).
    Returns 1.0 if nothing qualifies (the baseline itself)."""
    best = 1.0
    for acc, area in points:
        if acc >= baseline_acc - max_loss and area > 0:
            best = max(best, baseline_area / area)
    return best
