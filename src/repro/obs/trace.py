"""Ambient host-side tracing: nestable spans + structured events -> JSONL.

The switch mirrors the ``REPRO_VERIFY`` idiom (`repro.verify.diagnostics`):
``REPRO_TRACE`` unset/0/false/off means *off*, and off is free — ``span()``
returns a shared no-op context manager and ``event()`` returns after one
module-global load. No file is opened, no line is formatted, zero extra
syscalls. Set ``REPRO_TRACE=1`` for the default ``repro_trace.jsonl`` in
the working directory, or ``REPRO_TRACE=/path/to/run.jsonl`` to choose the
file. In-process control (benchmarks, tests) goes through
:func:`start` / :func:`stop` / :func:`capture`.

Records are append-only JSONL, one complete record per line, buffered and
written whole-lines-at-a-time — the same torn-write-safety convention as
`batch_eval.EvalCache`: a crash tears at most the trailing line, and
:func:`read_trace` salvages every complete leading record (the damaged
tail is counted, not fatal).

jit-boundary discipline (enforced by ``tools/jaxlint.py``'s ``obs-in-jit``
rule): spans wrap *dispatch* of jitted callables, never run inside traced
code — a span inside a jit body would fire at trace time, not run time,
and would try host IO under the tracer. The first dispatch of a jitted
callable includes XLA compilation; callers mark it via
:func:`first_call` so reports can split ``compile_ms`` from steady-state
execution instead of blaming the hot path for one-off trace+compile cost.

Span records carry ``ts`` (seconds since the tracer's ``start_unix``,
monotonic clock), ``dur``, ``depth`` (per-thread nesting), ``attrs``, and
``error`` (exception class name) when the body raised — the span is
emitted either way and the exception propagates untouched.
"""
from __future__ import annotations

import atexit
import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Tuple

ENV_FLAG = "REPRO_TRACE"

# records buffered before a write: amortizes syscalls on hot search loops
# while keeping the torn tail at most one buffer deep on a crash
BUFFER_LINES = 256


class Tracer:
    """One open JSONL sink. Thread-safe; spans nest per thread."""

    def __init__(self, path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._f = open(self.path, "a")
        self._lock = threading.Lock()
        self._buf: List[str] = []
        self._local = threading.local()
        self._seen_first: set = set()
        self.start_unix = time.time()
        self._t0 = time.monotonic()
        self.records = 0
        self._emit({"kind": "meta", "version": 1, "pid": os.getpid(),
                    "start_unix": self.start_unix})

    # -- record plumbing ---------------------------------------------------

    def now(self) -> float:
        return time.monotonic() - self._t0

    def _depth_stack(self) -> List[str]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _emit(self, rec: Dict[str, Any]) -> None:
        line = json.dumps(rec, separators=(",", ":"), default=str)
        with self._lock:
            self._buf.append(line)
            self.records += 1
            if len(self._buf) >= BUFFER_LINES:
                self._drain()

    def _drain(self) -> None:
        # whole lines in one write: a torn write can only damage the tail
        if self._buf:
            self._f.write("\n".join(self._buf) + "\n")
            self._buf.clear()

    def flush(self) -> None:
        with self._lock:
            self._drain()
            self._f.flush()

    def close(self) -> None:
        self.flush()
        self._f.close()

    def first(self, key) -> bool:
        with self._lock:
            if key in self._seen_first:
                return False
            self._seen_first.add(key)
            return True


class _NullSpan:
    """The off-path span: shared singleton, no state, no emission."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_t", "name", "attrs", "_start", "_depth")

    def __init__(self, tracer: Tracer, name: str, attrs: Dict[str, Any]):
        self._t = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs):
        """Attach attrs discovered mid-span (sizes, deltas)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        st = self._t._depth_stack()
        self._depth = len(st)
        st.append(self.name)
        self._start = self._t.now()
        return self

    def __exit__(self, etype, evalue, tb):
        dur = self._t.now() - self._start
        st = self._t._depth_stack()
        if st and st[-1] == self.name:
            st.pop()
        rec = {"kind": "span", "name": self.name,
               "ts": round(self._start, 6), "dur": round(dur, 6),
               "depth": self._depth}
        if self.attrs:
            rec["attrs"] = self.attrs
        if etype is not None:
            rec["error"] = etype.__name__
        self._t._emit(rec)
        return False                        # exceptions propagate untouched


# ---------------------------------------------------------------------------
# module-level switchboard
# ---------------------------------------------------------------------------

_tracer: Optional[Tracer] = None


def active() -> bool:
    """Is a tracer installed? The off-path is one global load."""
    return _tracer is not None


def tracing_to() -> Optional[Path]:
    return _tracer.path if _tracer is not None else None


def start(path=None) -> Tracer:
    """Install a tracer (replacing any current one). ``path`` defaults to
    the ``REPRO_TRACE`` value when it names a file, else
    ``repro_trace.jsonl`` in the working directory."""
    global _tracer
    if path is None:
        path = default_path()
    stop()
    _tracer = Tracer(path)
    return _tracer


def stop() -> None:
    """Flush, close and uninstall the current tracer (no-op when off)."""
    global _tracer
    if _tracer is not None:
        t, _tracer = _tracer, None
        t.close()


def flush() -> None:
    if _tracer is not None:
        _tracer.flush()


def default_path() -> Path:
    v = os.environ.get(ENV_FLAG, "")
    if v and ("/" in v or v.endswith(".jsonl")):
        return Path(v)
    return Path("repro_trace.jsonl")


def span(name: str, **attrs):
    """Context manager timing one host-side region. Zero-cost when off."""
    t = _tracer
    if t is None:
        return _NULL_SPAN
    return _Span(t, name, attrs)


def event(name: str, **attrs) -> None:
    """One instant structured record (ledger entries, per-generation
    stats). Zero-cost when off."""
    t = _tracer
    if t is None:
        return
    rec: Dict[str, Any] = {"kind": "event", "name": name,
                           "ts": round(t.now(), 6)}
    if attrs:
        rec["attrs"] = attrs
    t._emit(rec)


def first_call(key) -> bool:
    """True exactly once per ``key`` per tracer — mark the dispatch that
    includes jit compilation so reports split compile from steady-state.
    Always False when tracing is off (nothing tracks, nothing pays)."""
    t = _tracer
    if t is None:
        return False
    return t.first(key)


class capture:
    """``with capture(path):`` — scoped tracer for tests/benchmarks;
    restores the previously-installed tracer (or off) on exit."""

    def __init__(self, path):
        self.path = Path(path)
        self._prev: Optional[Tracer] = None

    def __enter__(self) -> Tracer:
        global _tracer
        self._prev = _tracer
        if self._prev is not None:
            self._prev.flush()
        _tracer = Tracer(self.path)
        return _tracer

    def __exit__(self, *exc):
        global _tracer
        if _tracer is not None:
            _tracer.close()
        _tracer = self._prev
        return False


# ---------------------------------------------------------------------------
# reading traces back (salvaging torn tails)
# ---------------------------------------------------------------------------


def read_trace(path) -> Tuple[List[Dict[str, Any]], int]:
    """-> (records, damaged_line_count). Every complete leading line
    parses; undecodable lines (torn tail after a crash mid-write, or
    fault-injected truncation) are counted and skipped — mirroring
    `EvalCache`'s salvage-don't-die convention."""
    records: List[Dict[str, Any]] = []
    damaged = 0
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                damaged += 1
                continue
            if isinstance(rec, dict):
                records.append(rec)
            else:
                damaged += 1
    return records, damaged


def _ambient_init() -> None:
    v = os.environ.get(ENV_FLAG, "").lower()
    if v not in ("", "0", "false", "off"):
        start()
        atexit.register(stop)


_ambient_init()


__all__ = ["ENV_FLAG", "Tracer", "active", "capture", "default_path",
           "event", "first_call", "flush", "read_trace", "span", "start",
           "stop", "tracing_to"]
