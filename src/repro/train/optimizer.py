"""Optimizers + LR schedules in pure JAX (optax is not in the container).

AdamW with fp32 master state regardless of param dtype (bf16 params at scale)
— the m/v/master leaves inherit the param sharding (FSDP over `data`
composes with TP over `model`: ZeRO-1/3 hybrid, DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"          # cosine | linear | constant
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        t = jnp.clip((step - cfg.warmup_steps) /
                     jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 1.0 - (1.0 - cfg.min_lr_frac) * t
    else:  # cosine
        t = jnp.clip((step - cfg.warmup_steps) /
                     jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
            (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * decay


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1t
        vh = v / b2t
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    # flatten to avoid is_leaf ambiguity (param trees contain tuple nodes)
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    triples = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    unf = jax.tree_util.tree_unflatten
    new_params = unf(treedef, [t[0] for t in triples])
    new_m = unf(treedef, [t[1] for t in triples])
    new_v = unf(treedef, [t[2] for t in triples])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, AdamWState(step, new_m, new_v), metrics
