"""Computation-environment configuration for multi-backend runs.

One place for the process-level JAX knobs the benchmarks, the search
runtime and the netlist-sim engines need when the repo leaves its default
CPU-pytest habitat: 64-bit lanes, platform selection (with the standard
GPU XLA flag set), host-device fan-out for pmap-style CPU runs, and NaN
debugging. All of these only take full effect at the beginning of the
program — call :func:`configure` (or the individual setters) before any
JAX computation, or drive them through the ``REPRO_*`` environment
variables it reads.

``default_netlist_engine`` is the routing policy for
`repro.kernels.netlist_sim`: the Pallas kernel where Pallas compiles to
real hardware (TPU), the wave-scheduled ``lax.scan`` engine everywhere
else (on CPU the Pallas path only exists in interpret mode, which is a
correctness oracle, not a fast path). ``REPRO_NETLIST_ENGINE`` overrides.
"""
from __future__ import annotations

import os
import warnings
from multiprocessing import cpu_count

import jax


def jax_enable_x64(use_x64: bool) -> None:
    """Default integer/float width 64 bits process-wide. The netlist-sim
    engines prefer the *local* ``jax.experimental.enable_x64`` scope and
    only need this for debugging sessions."""
    if not use_x64:
        use_x64 = bool(os.getenv("JAX_ENABLE_X64", 0))
    jax.config.update("jax_enable_x64", use_x64)


def set_platform(platform: str = "cpu") -> None:
    """Select 'cpu' | 'gpu' | 'tpu'. Only takes effect at the beginning of
    the program. GPU gets the standard performance flag set
    (<https://jax.readthedocs.io/en/latest/gpu_performance_tips.html>)."""
    jax.config.update("jax_platform_name", platform)
    if platform == "gpu":
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            " --xla_gpu_enable_triton_softmax_fusion=true"
            " --xla_gpu_triton_gemm_any=True"
            " --xla_gpu_enable_async_collectives=true"
            " --xla_gpu_enable_latency_hiding_scheduler=true"
            " --xla_gpu_enable_highest_priority_async_stream=true"
        ).strip()


def set_cpu_cores(n: int) -> None:
    """Expose ``n`` XLA host devices (for device-parallel CPU runs).
    CPU-platform only; must run before any JAX computation."""
    n = int(n)
    total = cpu_count()
    if n > total:
        warnings.warn(f"only {total} CPUs available, will use {total - 1}",
                      Warning)
        n = total - 1
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") +
        f" --xla_force_host_platform_device_count={n}").strip()


def set_debug_nan(flag: bool) -> None:
    """Raise on the first NaN any jitted computation produces."""
    jax.config.update("jax_debug_nans", flag)


def default_netlist_engine() -> str:
    """'pallas' on real TPU hardware, 'levels' elsewhere; overridable with
    ``REPRO_NETLIST_ENGINE=levels|pallas|ref``."""
    env = os.environ.get("REPRO_NETLIST_ENGINE", "").strip().lower()
    if env in ("levels", "pallas", "ref"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "levels"


def configure(*, platform: str | None = None, x64: bool | None = None,
              cpu_cores: int | None = None,
              debug_nan: bool | None = None) -> None:
    """Apply the requested knobs, falling back to ``REPRO_PLATFORM``,
    ``REPRO_X64``, ``REPRO_CPU_CORES`` and ``REPRO_DEBUG_NAN`` when an
    argument is None. Unset knobs are left at the JAX defaults."""
    def env(name):
        v = os.environ.get(name, "").strip()
        return v or None

    platform = platform if platform is not None else env("REPRO_PLATFORM")
    if platform:
        set_platform(platform)
    if x64 is None and env("REPRO_X64"):
        x64 = env("REPRO_X64") not in ("0", "false", "False")
    if x64 is not None:
        jax_enable_x64(bool(x64))
    cores = cpu_cores if cpu_cores is not None else env("REPRO_CPU_CORES")
    if cores:
        set_cpu_cores(int(cores))
    if debug_nan is None and env("REPRO_DEBUG_NAN"):
        debug_nan = env("REPRO_DEBUG_NAN") not in ("0", "false", "False")
    if debug_nan is not None:
        set_debug_nan(bool(debug_nan))
