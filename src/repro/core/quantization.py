"""Quantization / precision scaling (paper §II-A).

The paper quantizes MLP weights to 2–7 bits with QKeras-style
quantization-aware (re)training. We implement the same scheme natively:

* symmetric uniform quantizer with power-of-two or per-tensor max scaling
  (bespoke printed circuits multiply by the *fixed-point coefficient*, so the
  quantized integer grid is what the hardware sees);
* straight-through estimator (STE) for QAT — forward uses the quantized
  weight, backward passes gradients through unchanged;
* per-tensor and per-channel granularity (per-channel is the TPU-side
  `quant_matmul` kernel's native layout).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    bits: int = 8
    per_channel: bool = False          # scale per output channel (last dim)
    symmetric: bool = True
    po2_scale: bool = False            # power-of-two scale (pure shifts in HW)

    def __post_init__(self):
        assert 1 < self.bits <= 16, self.bits


def _scale(w: jnp.ndarray, qc: QuantConfig) -> jnp.ndarray:
    qmax = 2.0 ** (qc.bits - 1) - 1.0
    if qc.per_channel and w.ndim >= 2:
        amax = jnp.max(jnp.abs(w), axis=tuple(range(w.ndim - 1)),
                       keepdims=True)
    else:
        amax = jnp.max(jnp.abs(w))
    amax = jnp.maximum(amax, 1e-8)
    scale = amax / qmax
    if qc.po2_scale:
        scale = 2.0 ** jnp.ceil(jnp.log2(scale))
    return scale


def quantize_int(w: jnp.ndarray, qc: QuantConfig):
    """-> (q int32 in [-qmax, qmax], scale). w_hat = q * scale."""
    scale = _scale(w.astype(jnp.float32), qc)
    qmax = 2.0 ** (qc.bits - 1) - 1.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -qmax, qmax)
    return q.astype(jnp.int32), scale


def dequantize(q: jnp.ndarray, scale, dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def fake_quant(w: jnp.ndarray, qc: QuantConfig) -> jnp.ndarray:
    """Quantize-dequantize with STE: forward snaps to the grid, gradient is
    identity. This is the QAT forward used during (re)training."""
    def _fq(w):
        q, scale = quantize_int(w, qc)
        return dequantize(q, scale, w.dtype)
    # forward: fq(w); backward: identity (the correction term carries no grad)
    return w + (_fq(jax.lax.stop_gradient(w)) - jax.lax.stop_gradient(w))


def fake_quant_tree(params, bits_tree):
    """Apply fake-quant leaf-wise. ``bits_tree``: pytree-prefix of ints or
    None (None = leave leaf in full precision)."""
    def fq(w, bits):
        if bits is None or w.ndim == 0:
            return w
        return fake_quant(w, QuantConfig(bits=int(bits)))
    return jax.tree_util.tree_map(fq, params, bits_tree,
                                  is_leaf=lambda x: x is None)


def quant_error(w: jnp.ndarray, qc: QuantConfig) -> float:
    """Relative L2 quantization error — used by the GA's cheap fitness proxy."""
    q, s = quantize_int(w, qc)
    err = jnp.linalg.norm(w - dequantize(q, s)) / \
        jnp.maximum(jnp.linalg.norm(w), 1e-9)
    return float(err)
