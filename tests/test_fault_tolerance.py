"""Edge cases of the deadline/straggler primitives (`dist.fault_tolerance`)
that the island-model search runtime now leans on."""
import pytest

from repro.dist import fault_tolerance as FT


def test_deadline_barrier_basic():
    assert FT.deadline_barrier([0.1, 2.0, 0.5], 1.0) == [True, False, True]
    # boundary is inclusive: arriving exactly at the deadline participates
    assert FT.deadline_barrier([1.0], 1.0) == [True]


def test_deadline_barrier_infinite_deadline_admits_all_but_inf():
    inf = float("inf")
    # inf <= inf — a dead host reporting inf still "makes" an infinite
    # deadline; callers (the island fleet) must mask dead hosts themselves
    assert FT.deadline_barrier([0.0, inf], inf) == [True, True]


def test_redistribute_all_hosts_straggle_raises():
    with pytest.raises(RuntimeError):
        FT.redistribute_batch(128, [False, False, False])
    with pytest.raises(RuntimeError):
        FT.redistribute_batch(0, [])


def test_redistribute_single_survivor_takes_everything():
    deal = FT.redistribute_batch(100, [False, True, False, False])
    assert deal == {0: 0, 1: 100, 2: 0, 3: 0}


def test_redistribute_zero_batch():
    deal = FT.redistribute_batch(0, [True, True, True])
    assert deal == {0: 0, 1: 0, 2: 0}
    assert sum(deal.values()) == 0


@pytest.mark.parametrize("batch,alive", [
    (7, [True, True, True]),          # odd over 3
    (10, [True, False, True, True]),  # odd share over 3 survivors
    (1, [True, True]),                # fewer examples than hosts
    (97, [True] * 8),
])
def test_redistribute_sums_exact_and_balanced(batch, alive):
    deal = FT.redistribute_batch(batch, alive)
    assert sum(deal.values()) == batch
    shares = [deal[i] for i, ok in enumerate(alive) if ok]
    dead = [deal[i] for i, ok in enumerate(alive) if not ok]
    assert all(d == 0 for d in dead)
    assert max(shares) - min(shares) <= 1


def test_should_checkpoint_now_cadence():
    hits = [s for s in range(1, 11)
            if FT.should_checkpoint_now(s, every=3,
                                        preemption_requested=False)]
    assert hits == [3, 6, 9]


def test_should_checkpoint_now_preemption_overrides():
    # off-cadence step still checkpoints under a preemption notice
    assert FT.should_checkpoint_now(7, every=3, preemption_requested=True)
    # even with cadence disabled entirely
    assert FT.should_checkpoint_now(7, every=0, preemption_requested=True)
    assert not FT.should_checkpoint_now(7, every=0,
                                        preemption_requested=False)
