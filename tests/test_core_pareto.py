"""Direct unit tests for `core.pareto` — non-domination edge cases,
K>=3 objectives, crowding-distance ties (previously only exercised
indirectly through the GA tests)."""
import numpy as np
import pytest

from repro.core import pareto as PR


# ---------------------------------------------------------------------------
# dominates
# ---------------------------------------------------------------------------


def test_dominates_strictness():
    assert PR.dominates([0, 0], [1, 1])
    assert PR.dominates([0, 1], [1, 1])
    assert not PR.dominates([1, 1], [1, 1])       # equality never dominates
    assert not PR.dominates([0, 2], [1, 1])       # trade-off
    assert PR.dominates([1, 2, 3], [1, 2, 4])     # K=3, one strict axis


# ---------------------------------------------------------------------------
# non-dominated sorting
# ---------------------------------------------------------------------------


def test_single_point_front():
    fronts = PR.non_dominated_sort(np.array([[3.0, 4.0]]))
    assert len(fronts) == 1
    assert fronts[0].tolist() == [0]


def test_duplicate_points_share_a_front():
    """Equal points never dominate each other, so every duplicate of a
    non-dominated point sits on the first front."""
    pts = np.array([[0.0, 0.0], [0.0, 0.0], [0.0, 0.0], [1.0, 1.0]])
    fronts = PR.non_dominated_sort(pts)
    assert set(fronts[0].tolist()) == {0, 1, 2}
    assert set(fronts[1].tolist()) == {3}


def test_all_identical_points_one_front():
    pts = np.ones((5, 3))
    fronts = PR.non_dominated_sort(pts)
    assert len(fronts) == 1
    assert set(fronts[0].tolist()) == set(range(5))


def test_three_objectives_layering():
    pts = np.array([
        [0.0, 0.0, 0.0],        # dominates everything
        [1.0, 0.0, 0.0],        # front 2 (dominated only by 0)
        [0.0, 1.0, 0.0],        # front 2
        [1.0, 1.0, 1.0],        # front 3
        [2.0, 0.0, 0.0],        # front 3 (dominated by 1)
    ])
    fronts = PR.non_dominated_sort(pts)
    assert fronts[0].tolist() == [0]
    assert set(fronts[1].tolist()) == {1, 2}
    assert set(fronts[2].tolist()) == {3, 4}


def test_fronts_partition_and_respect_domination():
    rng = np.random.default_rng(3)
    pts = rng.random((40, 3))
    fronts = PR.non_dominated_sort(pts)
    flat = [i for f in fronts for i in f.tolist()]
    assert sorted(flat) == list(range(40))        # exact partition
    # no member of front k is dominated by any member of front >= k
    for k, f in enumerate(fronts):
        later = [i for g in fronts[k:] for i in g.tolist()]
        for i in f:
            assert not any(PR.dominates(pts[j], pts[i]) for j in later)


# ---------------------------------------------------------------------------
# crowding distance
# ---------------------------------------------------------------------------


def test_crowding_small_fronts_are_infinite():
    assert np.all(np.isinf(PR.crowding_distance(np.array([[1.0, 2.0]]))))
    assert np.all(np.isinf(PR.crowding_distance(
        np.array([[1.0, 2.0], [2.0, 1.0]]))))


def test_crowding_boundaries_infinite_interior_finite():
    pts = np.array([[0.0, 3.0], [1.0, 2.0], [2.0, 1.0], [3.0, 0.0]])
    d = PR.crowding_distance(pts)
    assert np.isinf(d[0]) and np.isinf(d[3])
    assert np.isfinite(d[1]) and np.isfinite(d[2])
    assert d[1] == pytest.approx(d[2])            # symmetric spacing ties


def test_crowding_degenerate_axis_is_skipped():
    """A zero-range objective must not divide by zero; remaining axes
    still discriminate."""
    pts = np.array([[0.0, 5.0], [1.0, 5.0], [2.0, 5.0], [9.0, 5.0]])
    d = PR.crowding_distance(pts)
    assert np.all(np.isfinite(d[1:3]))
    assert d[2] > d[1]                             # 9 is farther from 1
    # fully degenerate: every axis tied -> only boundary infinities
    dd = PR.crowding_distance(np.ones((4, 2)))
    assert np.isinf(dd).sum() >= 2
    assert np.all(dd[np.isfinite(dd)] == 0.0)


def test_crowding_three_objectives_accumulates_axes():
    pts = np.array([[0.0, 0.0, 2.0], [1.0, 1.0, 1.0], [2.0, 2.0, 0.0],
                    [3.0, 3.0, 3.0]])
    d = PR.crowding_distance(pts)
    assert d.shape == (4,)
    assert np.isfinite(d[1]) and d[1] > 0


# ---------------------------------------------------------------------------
# hypervolume + the paper's gain metric
# ---------------------------------------------------------------------------


def test_hypervolume_rectangle():
    hv = PR.hypervolume_2d(np.array([[0.5, 0.5]]), (1.0, 1.0))
    assert hv == pytest.approx(0.25)
    # points at/beyond the reference contribute nothing
    assert PR.hypervolume_2d(np.array([[1.0, 0.2], [0.2, 1.0]]),
                             (1.0, 1.0)) == 0.0


def test_hypervolume_ignores_dominated_points():
    a = np.array([[0.2, 0.2]])
    b = np.array([[0.2, 0.2], [0.5, 0.5]])        # dominated adds nothing
    assert PR.hypervolume_2d(a, (1, 1)) == pytest.approx(
        PR.hypervolume_2d(b, (1, 1)))


def test_gain_at_loss_nothing_qualifies():
    pts = [(0.5, 10.0)]                           # way below the acc floor
    assert PR.gain_at_loss(pts, baseline_acc=0.9, baseline_area=100.0,
                           max_loss=0.05) == 1.0


def test_gain_at_loss_picks_max_gain_within_floor():
    pts = [(0.89, 50.0), (0.86, 10.0), (0.84, 1.0)]
    g = PR.gain_at_loss(pts, baseline_acc=0.90, baseline_area=100.0,
                        max_loss=0.05)
    assert g == pytest.approx(10.0)               # 0.84 misses the floor
