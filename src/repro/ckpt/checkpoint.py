"""Fault-tolerant checkpointing: per-leaf npz shards, atomic renames, an
async writer thread, and elastic resharding on restore.

Layout (one directory per step):
    <root>/step_000420.tmp/...   (written)
    <root>/step_000420/          (atomic rename on completion)
        MANIFEST.json            (treedef, leaf paths/shapes/dtypes, meta)
        leaf_000000.npy ...

Restore never requires the saving mesh: leaves are stored unsharded (host
gathers), so a checkpoint written on a 256-chip pod restores onto 512 chips
or 8 (elastic scaling) — resharding happens at `jax.device_put` time against
the new mesh's NamedShardings. For 1000+-node scale the same layout shards
per-host (each host writes its addressable slice); single-process here, so
the gather is a no-op.
"""
from __future__ import annotations

import json
import queue
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.dist.sharding import path_str


class CheckpointManager:
    def __init__(self, root, *, keep: int = 3, async_write: bool = True):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._q: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- public ------------------------------------------------------------

    def save(self, step: int, tree, *, meta: Optional[Dict] = None,
             block: bool = False):
        """Snapshot `tree` at `step`. Device->host copy happens synchronously
        (consistent snapshot); disk IO is offloaded to the writer thread."""
        self._raise_pending()
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host = [np.asarray(l) for l in leaves]     # sync gather
        paths = [path_str(p) for p, _ in
                 jax.tree_util.tree_leaves_with_path(tree)]
        job = (int(step), host, str(treedef), paths, meta or {})
        if self.async_write:
            self._ensure_worker()
            self._q.put(job)
            if block:
                self._q.join()
        else:
            self._write(job)

    def restore(self, step: Optional[int] = None, *, like=None,
                shardings=None):
        """Load step (default latest). `like`: pytree prototype used to
        unflatten; `shardings`: optional pytree of NamedSharding to place
        leaves onto the *current* mesh (elastic reshard)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        d = self.root / f"step_{step:08d}"
        manifest = json.loads((d / "MANIFEST.json").read_text())
        leaves = []
        for i in range(manifest["n_leaves"]):
            arr = np.load(d / f"leaf_{i:06d}.npy")
            want = manifest["dtypes"][i]
            if str(arr.dtype) != want:
                # extended dtypes (bfloat16 etc.) stored as byte views
                import ml_dtypes  # ships with jax
                arr = arr.view(np.dtype(getattr(ml_dtypes, want)))
            leaves.append(arr)
        if like is not None:
            treedef = jax.tree_util.tree_structure(like)
            tree = jax.tree_util.tree_unflatten(treedef, leaves)
            if shardings is not None:
                flat_s = treedef.flatten_up_to(shardings)
                flat_l = treedef.flatten_up_to(tree)
                tree = jax.tree_util.tree_unflatten(
                    treedef,
                    [jax.device_put(l, s) for l, s in zip(flat_l, flat_s)])
            return tree, manifest["meta"]
        return leaves, manifest["meta"]

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self) -> List[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.root.iterdir()
                      if p.is_dir() and p.name.startswith("step_")
                      and not p.name.endswith(".tmp"))

    def wait(self):
        if self._worker is not None:
            self._q.join()
        self._raise_pending()

    # -- internals -----------------------------------------------------------

    def _ensure_worker(self):
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._loop, daemon=True)
            self._worker.start()

    def _loop(self):
        while True:
            job = self._q.get()
            try:
                self._write(job)
            except BaseException as e:  # surfaced on next save()/wait()
                self._error = e
            finally:
                self._q.task_done()

    def _raise_pending(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _write(self, job):
        step, host, treedef_str, paths, meta = job
        final = self.root / f"step_{step:08d}"
        tmp = self.root / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        for i, arr in enumerate(host):
            if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
                # extended dtype (bfloat16, fp8): store a same-width byte view
                arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
            np.save(tmp / f"leaf_{i:06d}.npy", arr)
        manifest = {
            "step": step, "n_leaves": len(host), "treedef": treedef_str,
            "paths": paths, "meta": meta, "time": time.time(),
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
        }
        (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                      # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)
