"""GA hot-path benchmark: serial vs batched population evaluation.

Measures the wall-clock of evaluating one NSGA-II generation (population of
fresh specs) on the paper's UCI MLPs two ways:

* serial   — `minimize.evaluate_spec` per candidate (a fresh `jax.jit`
             trace of the QAT train loop for every spec);
* batched  — `batch_eval.evaluate_population` (one vmap-over-scan jit for
             the whole population + one vectorized pricing pass).

Reports per-generation wall-clock, the speedup, and the max deviation of
the batched objectives from the serial ones (the engines are designed to
match exactly; the acceptance bar is 1e-3). A warm second batched
generation is also timed — that is the steady-state GA cost, where the
population jit is already compiled.

The warm generation is then re-run under a live `repro.obs` tracer: the
bench emits the trace JSONL (eval.batch / eval.finetune /
eval.compile_price spans for the whole stack) and reports the relative
tracing overhead against the untraced warm lap.
"""
from __future__ import annotations

import random
import tempfile
import time
from pathlib import Path
from typing import Dict, List

import numpy as np

from repro.obs import trace as TR

from repro.configs.printed_mlp import PRINTED_MLPS
from repro.core import batch_eval as BE
from repro.core import ga as GA
from repro.core import minimize as MZ
from repro.core.compression_spec import ModelMin


def _random_population(n_layers: int, population: int,
                       seed: int) -> List[ModelMin]:
    rng = random.Random(seed)
    cfg = GA.GAConfig()
    return [ModelMin(tuple(GA._random_gene(rng, cfg)
                           for _ in range(n_layers)))
            for _ in range(population)]


def run(dataset: str = "whitewine", *, population: int = 16,
        epochs: int = 90, seed: int = 0) -> Dict:
    cfg = PRINTED_MLPS[dataset]
    n_layers = len(cfg.layer_dims) - 1
    MZ.pretrain(cfg, seed=seed)          # shared across both paths

    gen0 = _random_population(n_layers, population, seed)
    gen1 = _random_population(n_layers, population, seed + 1)

    t0 = time.time()
    serial = [MZ.evaluate_spec(cfg, s, epochs=epochs, seed=seed)
              for s in gen0]
    t_serial = time.time() - t0

    t0 = time.time()
    batched = BE.evaluate_population(cfg, gen0, epochs=epochs, seed=seed)
    t_batched = time.time() - t0

    t0 = time.time()
    BE.evaluate_population(cfg, gen1, epochs=epochs, seed=seed)
    t_warm = time.time() - t0

    # same warm generation under a live tracer: emit the trace file and
    # price the telemetry against the untraced warm lap
    trace_path = Path(tempfile.mkdtemp(prefix="repro_obs_bench_")) \
        / "ga_bench_trace.jsonl"
    with TR.capture(trace_path):
        t0 = time.time()
        BE.evaluate_population(cfg, gen1, epochs=epochs, seed=seed)
        t_traced = time.time() - t0
    records, damaged = TR.read_trace(trace_path)
    assert damaged == 0 and records, "bench trace unreadable"
    trace_overhead = max(0.0, t_traced / t_warm - 1.0)

    sobj = np.array([(1.0 - r.accuracy, r.area_mm2) for r in serial])
    bobj = np.array([(1.0 - r.accuracy, r.area_mm2) for r in batched])
    dev = np.abs(sobj - bobj)
    max_dev = float(np.max([dev[:, 0].max(),
                            (dev[:, 1] / np.maximum(sobj[:, 1], 1)).max()]))

    return {
        "dataset": dataset, "population": population, "epochs": epochs,
        "t_serial_s": t_serial, "t_batched_s": t_batched,
        "t_batched_warm_s": t_warm,
        "speedup": t_serial / t_batched,
        "speedup_warm": t_serial / t_warm,
        "max_objective_deviation": max_dev,
        "t_traced_s": t_traced,
        "trace_overhead_pct": trace_overhead * 100.0,
        "trace_path": str(trace_path),
        "trace_records": len(records),
    }


def main(fast: bool = False):
    kw = dict(population=8, epochs=40) if fast else {}
    res = run(**kw)
    print("ga_bench (one NSGA-II generation: serial evaluate_spec vs "
          "batched engine)")
    print(f"dataset={res['dataset']} population={res['population']} "
          f"epochs={res['epochs']}")
    print(f"  serial        {res['t_serial_s']:7.1f} s/generation")
    print(f"  batched       {res['t_batched_s']:7.1f} s/generation "
          f"({res['speedup']:.1f}x)")
    print(f"  batched warm  {res['t_batched_warm_s']:7.1f} s/generation "
          f"({res['speedup_warm']:.1f}x)  <- steady-state GA cost")
    print(f"  max objective deviation vs serial: "
          f"{res['max_objective_deviation']:.2e} (bar: 1e-3)")
    print(f"  tracing overhead {res['trace_overhead_pct']:6.2f} % on the "
          f"warm lap ({res['trace_records']} records -> "
          f"{res['trace_path']})")
    ok = res["speedup"] >= 3.0 and res["max_objective_deviation"] <= 1e-3
    print(f"  acceptance (>=3x, <=1e-3): {'PASS' if ok else 'FAIL'}")
    return res


if __name__ == "__main__":
    main()
