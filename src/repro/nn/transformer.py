"""Model assembly: blocks -> segments -> language model.

Depth is executed as ``lax.scan`` over *segments*: each segment stacks the
parameters of its repeating pattern along a leading ``repeats`` axis, so the
HLO is O(pattern length), not O(num_layers). Heterogeneous stacks (gemma2
local/global alternation, griffin rec-rec-local, VLM cross-attn every 5th
layer, deepseek dense-then-MoE) are expressed as patterns, never as traced
branches — FLOP accounting in the roofline stays exact.

Public entry points:
  init(key, cfg)                     -> params pytree
  forward(params, batch, cfg)        -> logits (train / prefill)
  decode_step(params, state, tok, cfg) -> (logits, state)  (one-token serve)
  init_decode_state(cfg, batch, max_len, dtype) -> cache pytree
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec, Segment
from repro.nn import attention as A
from repro.nn import layers as L
from repro.nn import moe as M
from repro.nn import rglru as R
from repro.nn import ssm as S

# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------


def _block_init(key, cfg: ArchConfig, spec: LayerSpec, dtype):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": L.norm_init(cfg.d_model, cfg.norm_type)}
    if spec.mixer in ("attn", "local"):
        if cfg.mla is not None:
            p["mixer"] = A.mla_init(ks[0], cfg, dtype)
        else:
            p["mixer"] = A.attn_init(ks[0], cfg, dtype)
    elif spec.mixer == "cross":
        p["mixer"] = A.attn_init(ks[0], cfg, dtype, cross=True)
        p["cross_gate"] = jnp.zeros((), jnp.float32)
        p["norm_cross"] = L.norm_init(cfg.d_model, cfg.norm_type)
    elif spec.mixer == "ssm":
        p["mixer"] = S.ssm_init(ks[0], cfg, dtype)
    elif spec.mixer == "rec":
        p["mixer"] = R.rglru_init(ks[0], cfg, dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn == "dense":
        p["norm2"] = L.norm_init(cfg.d_model, cfg.norm_type)
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
    elif spec.ffn == "moe":
        p["norm2"] = L.norm_init(cfg.d_model, cfg.norm_type)
        p["moe"] = M.moe_init(ks[1], cfg, dtype)
    if cfg.post_norm:
        p["post_norm1"] = L.norm_init(cfg.d_model, cfg.norm_type)
        if spec.ffn != "none":
            p["post_norm2"] = L.norm_init(cfg.d_model, cfg.norm_type)
    return p


def _norm(p, x, cfg):
    return L.norm_apply(p, x, cfg.norm_type, unit_offset=cfg.norm_unit_offset)


def _block_apply(p, x, cfg: ArchConfig, spec: LayerSpec, *, cache=None,
                 kv_len=None, enc_out=None, positions=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = _norm(p["norm1"], x, cfg)
    if spec.mixer in ("attn", "local"):
        if cfg.mla is not None:
            o, new_cache = A.mla_apply(p["mixer"], h, cfg, cache=cache,
                                       kv_len=kv_len)
        else:
            o, new_cache = A.attn_apply(p["mixer"], h, cfg, mixer=spec.mixer,
                                        cache=cache, kv_len=kv_len,
                                        positions=positions)
    elif spec.mixer == "cross":
        # self-attention sublayer, then a gated cross-attention sublayer
        o, new_cache = A.attn_apply(p["mixer"], h, cfg, mixer="attn",
                                    cache=cache, kv_len=kv_len,
                                    positions=positions)
        if cfg.post_norm:
            o = _post(p, "post_norm1", o, cfg)
        x = x + o
        hc = _norm(p["norm_cross"], x, cfg)
        cq = L.dense_apply(p["mixer"]["c_wq"], hc)
        ek = L.dense_apply(p["mixer"]["c_wk"], enc_out)
        ev = L.dense_apply(p["mixer"]["c_wv"], enc_out)
        co = A.attend(cq, ek, ev, causal=False)
        o = jnp.tanh(p["cross_gate"]).astype(x.dtype) * L.dense_in3_apply(
            p["mixer"]["c_wo"], co).astype(x.dtype)
        x = x + o
        o = jnp.zeros_like(x)  # residual already applied above
    elif spec.mixer == "ssm":
        o, new_cache = S.ssm_apply(p["mixer"], h, cfg, cache=cache)
    elif spec.mixer == "rec":
        o, new_cache = R.rglru_apply(p["mixer"], h, cfg, cache=cache)
    else:
        raise ValueError(spec.mixer)
    if cfg.post_norm:
        o = _post(p, "post_norm1", o, cfg)
    x = x + o

    if spec.ffn == "dense":
        o = L.mlp_apply(p["mlp"], _norm(p["norm2"], x, cfg), cfg.mlp_type)
        if cfg.post_norm:
            o = _post(p, "post_norm2", o, cfg)
        x = x + o
    elif spec.ffn == "moe":
        o, aux = M.moe_apply(p["moe"], _norm(p["norm2"], x, cfg), cfg)
        if cfg.post_norm:
            o = _post(p, "post_norm2", o, cfg)
        x = x + o
    return x, new_cache, aux


def _post(p, name, o, cfg):
    return L.norm_apply(p[name], o, cfg.norm_type,
                        unit_offset=cfg.norm_unit_offset)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def _layer_cache(cfg: ArchConfig, spec: LayerSpec, batch: int, max_len: int,
                 dtype):
    if spec.mixer in ("attn", "cross"):
        if cfg.mla is not None:
            return A.make_mla_cache(cfg, batch, max_len, dtype)
        return A.make_attn_cache(cfg, batch, max_len, dtype, mixer="attn")
    if spec.mixer == "local":
        if cfg.mla is not None:
            return A.make_mla_cache(cfg, batch, max_len, dtype)
        return A.make_attn_cache(cfg, batch, max_len, dtype, mixer="local")
    if spec.mixer == "ssm":
        return S.make_ssm_cache(cfg, batch, dtype)
    if spec.mixer == "rec":
        return R.make_rglru_cache(cfg, batch, dtype)
    raise ValueError(spec.mixer)


# ---------------------------------------------------------------------------
# segments (scan over repeats)
# ---------------------------------------------------------------------------


def _segment_init(key, cfg: ArchConfig, seg: Segment, dtype):
    """Stack pattern-position params along a leading `repeats` axis."""
    def one_repeat(k):
        kk = jax.random.split(k, len(seg.pattern))
        return tuple(_block_init(kk[i], cfg, spec, dtype)
                     for i, spec in enumerate(seg.pattern))
    keys = jax.random.split(key, seg.repeats)
    per_repeat = [one_repeat(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_repeat)


def _segment_apply(seg_params, x, cfg: ArchConfig, seg: Segment, *,
                   caches=None, kv_len=None, enc_out=None, positions=None,
                   remat: bool = True, unroll: bool = False):
    """Scan the repeating pattern. caches: stacked pytree (leading=repeats) or
    None. Returns (x, new_caches, aux_sum). ``unroll=True`` replaces the scan
    with a python loop — used by the roofline dry-run, where XLA's
    cost_analysis counts a while body once regardless of trip count."""

    def body(carry, xs):
        x, aux = carry
        params, cache_in = xs
        new_caches = []
        for i, spec in enumerate(seg.pattern):
            c = None if cache_in is None else cache_in[i]
            x, nc, a = _block_apply(params[i], x, cfg, spec, cache=c,
                                    kv_len=kv_len, enc_out=enc_out,
                                    positions=positions)
            new_caches.append(nc)
            aux = aux + a
        out_caches = None if cache_in is None else tuple(new_caches)
        return (x, aux), out_caches

    if remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)

    xs = (seg_params, caches)
    if unroll:
        carry = (x, jnp.zeros((), jnp.float32))
        ys = []
        for r in range(seg.repeats):
            xs_r = jax.tree_util.tree_map(lambda a, r=r: a[r], xs)
            carry, y = body(carry, xs_r)
            ys.append(y)
        (x, aux) = carry
        new_caches = (None if caches is None else
                      jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *ys))
        return x, new_caches, aux
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


def init(key, cfg: ArchConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {
        "embed": L.embedding_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "segments": tuple(
            _segment_init(k, cfg, seg, dtype)
            for k, seg in zip(jax.random.split(ks[1], len(cfg.segments)),
                              cfg.segments)),
        "final_norm": L.norm_init(cfg.d_model, cfg.norm_type),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(ks[2], cfg.d_model, cfg.vocab_size, dtype)
    if cfg.max_position_embeddings:
        p["pos_embed"] = L.positional_init(
            ks[3], cfg.max_position_embeddings, cfg.d_model, dtype)
    if cfg.encoder is not None:
        enc_seg = Segment((LayerSpec("attn", "dense"),), cfg.encoder.num_layers)
        # encoder is bidirectional: reuse attn params, applied non-causally
        p["encoder"] = {
            "segments": (_segment_init(ks[4], cfg, enc_seg, dtype),),
            "final_norm": L.norm_init(cfg.d_model, cfg.norm_type),
            "pos_embed": L.positional_init(
                ks[5], cfg.encoder.num_frames, cfg.d_model, dtype),
        }
    return p


def _encoder_forward(p, frames, cfg: ArchConfig, *, remat=True,
                     unroll: bool = False):
    """frames: stub embeddings (B, F, d_model) — the conv frontend is a stub
    per the assignment; positions are learned."""
    x = frames + p["pos_embed"]["table"][None, :frames.shape[1]].astype(frames.dtype)
    enc_seg = Segment((LayerSpec("attn", "dense"),), cfg.encoder.num_layers)

    def body(carry, params):
        x, _ = carry
        blk = params[0]
        h = _norm(blk["norm1"], x, cfg)
        o = A.encoder_attn_apply(blk["mixer"], h, cfg)
        x = x + o
        o = L.mlp_apply(blk["mlp"], _norm(blk["norm2"], x, cfg), cfg.mlp_type)
        x = x + o
        return (x, jnp.zeros((), jnp.float32)), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    if unroll:
        carry = (x, jnp.zeros((), jnp.float32))
        n = cfg.encoder.num_layers
        for r in range(n):
            carry, _ = body(carry, jax.tree_util.tree_map(
                lambda a, r=r: a[r], p["segments"][0]))
        x = carry[0]
    else:
        (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                 p["segments"][0])
    return _norm(p["final_norm"], x, cfg)


def _embed_tokens(p, tokens, cfg: ArchConfig, offset=None):
    x = L.embedding_apply(p["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.max_position_embeddings:
        T = tokens.shape[1]
        if offset is None:
            pos = p["pos_embed"]["table"][None, :T]
        else:
            start = jnp.minimum(offset, cfg.max_position_embeddings - T)
            pos = jax.lax.dynamic_slice_in_dim(
                p["pos_embed"]["table"], start, T, axis=0)[None]
        x = x + pos.astype(x.dtype)
    return x


def _lm_head(p, x, cfg: ArchConfig):
    if cfg.tie_embeddings:
        logits = jnp.einsum("btd,vd->btv", x, p["embed"]["table"])
    else:
        logits = L.dense_apply(p["lm_head"], x)
    return L.softcap(logits.astype(jnp.float32), cfg.logit_softcap)


def forward(params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig, *,
            remat: bool = True, unroll: bool = False):
    """Train/prefill forward. batch: {"tokens": (B,T)[, "frames": (B,F,d)]
    [, "patches": (B,P,d)]}. Returns (logits fp32 (B,T,V), aux_loss)."""
    tokens = batch["tokens"]
    x = _embed_tokens(params, tokens, cfg)
    enc_out = None
    if cfg.encoder is not None:
        enc_out = _encoder_forward(params["encoder"], batch["frames"], cfg,
                                   remat=remat, unroll=unroll)
    elif cfg.vision is not None:
        enc_out = batch["patches"]          # stub patch embeddings at d_model
    aux_total = jnp.zeros((), jnp.float32)
    for seg_params, seg in zip(params["segments"], cfg.segments):
        x, _, aux = _segment_apply(seg_params, x, cfg, seg, enc_out=enc_out,
                                   remat=remat, unroll=unroll)
        aux_total = aux_total + aux
    x = _norm(params["final_norm"], x, cfg)
    return _lm_head(params, x, cfg), aux_total


# ---------------------------------------------------------------------------
# decoding
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int, dtype):
    """Stacked caches mirroring the segment structure + kv_len counter +
    (enc-dec/VLM) encoder context placeholder."""
    caches = []
    for seg in cfg.segments:
        def one(spec):
            return _layer_cache(cfg, spec, batch, max_len, dtype)
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[tuple(one(spec) for spec in seg.pattern)
              for _ in range(seg.repeats)])
        caches.append(stacked)
    state = {"caches": tuple(caches),
             "kv_len": jnp.zeros((), jnp.int32)}
    if cfg.encoder is not None:
        state["enc_out"] = jnp.zeros(
            (batch, cfg.encoder.num_frames, cfg.d_model), dtype)
    elif cfg.vision is not None:
        state["enc_out"] = jnp.zeros(
            (batch, cfg.vision.num_patches, cfg.d_model), dtype)
    return state


def decode_step(params, state, tokens, cfg: ArchConfig, *,
                unroll: bool = False):
    """One-token decode. tokens: (B, 1). Returns (logits (B,1,V), new state)."""
    kv_len = state["kv_len"]
    x = _embed_tokens(params, tokens, cfg, offset=kv_len)
    enc_out = state.get("enc_out")
    new_caches = []
    for seg_params, seg, caches in zip(params["segments"], cfg.segments,
                                       state["caches"]):
        x, nc, _ = _segment_apply(seg_params, x, cfg, seg, caches=caches,
                                  kv_len=kv_len, enc_out=enc_out, remat=False,
                                  unroll=unroll)
        new_caches.append(nc)
    x = _norm(params["final_norm"], x, cfg)
    logits = _lm_head(params, x, cfg)
    new_state = dict(state)
    new_state["caches"] = tuple(new_caches)
    new_state["kv_len"] = kv_len + tokens.shape[1]
    return logits, new_state


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def active_param_count(params, cfg: ArchConfig) -> int:
    """MoE-aware: routed experts count at top_k/E fraction (+ shared fully)."""
    total = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        n = int(leaf.size)
        if cfg.moe is not None and "experts" in keys:
            n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
        total += n
    return total
