"""Quantized serving formats (serve/quantized.py): round-trip accuracy and
decode-path agreement — the §Perf w4tp/w8 variants must be *correct*, not
just smaller."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.nn import transformer as T
from repro.serve import quantized as QS


def _setup(name="qwen3-0.6b", d_model=128):
    cfg = ARCHS[name].reduced(vocab_size=512, d_model=d_model, num_heads=4,
                              num_kv_heads=2, head_dim=32, d_ff=256)
    params = T.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_quantize_dequantize_roundtrip_error():
    cfg, params = _setup()
    for bits, tol in ((8, 0.006), (4, 0.10)):
        qp = QS.quantize_params(params, bits=bits)
        dq = QS.dequantize_params(qp, jnp.float32)
        for (pa, a), (pb, b) in zip(
                jax.tree_util.tree_leaves_with_path(params),
                jax.tree_util.tree_leaves_with_path(dq)):
            if a.ndim >= 2 and a.size >= (1 << 16):
                rel = float(jnp.linalg.norm(a - b) / jnp.linalg.norm(a))
                assert rel < tol, (bits, rel)


def test_quantized_leaves_are_int():
    cfg, params = _setup()
    qp = QS.quantize_params(params, bits=8)
    flat = jax.tree_util.tree_leaves(qp)
    n_int8 = sum(1 for l in flat if l.dtype == jnp.int8)
    assert n_int8 > 0
    # every int8 leaf pairs with a replicated fp32 scale leaf
    n_qleaves = sum(1 for x in jax.tree_util.tree_leaves(
        qp, is_leaf=QS.is_qleaf) if QS.is_qleaf(x))
    assert n_qleaves == n_int8


def test_int8_decode_matches_fp_decode():
    cfg, params = _setup()
    qp = QS.quantize_params(params, bits=8)
    step_fp = jax.jit(lambda p, s, t: T.decode_step(p, s, t, cfg))
    qstep = jax.jit(QS.make_quant_serve_step(
        dataclasses.replace(cfg, dtype="float32")))
    B = 2
    s1 = T.init_decode_state(cfg, B, 16, jnp.float32)
    s2 = T.init_decode_state(cfg, B, 16, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 5), 0,
                              cfg.vocab_size)
    match = 0
    for t in range(5):
        logits, s1 = step_fp(params, s1, toks[:, t:t + 1])
        nxt_q, s2 = qstep(qp, s2, toks[:, t:t + 1])
        nxt_fp = jnp.argmax(logits[:, -1], -1)
        match += int(jnp.sum(nxt_fp == nxt_q[:, 0]))
    assert match >= 8, f"int8 greedy tokens diverge too much: {match}/10"


def test_fp8_kv_cache_decode_close():
    cfg, params = _setup()
    B = 2
    s_fp = T.init_decode_state(cfg, B, 16, jnp.float32)
    s_f8 = T.init_decode_state(cfg, B, 16, jnp.float8_e4m3fn)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 6), 0,
                              cfg.vocab_size)
    step = jax.jit(lambda p, s, t: T.decode_step(p, s, t, cfg))
    for t in range(6):
        l_fp, s_fp = step(params, s_fp, toks[:, t:t + 1])
        l_f8, s_f8 = step(params, s_f8, toks[:, t:t + 1])
    # fp8 KV introduces bounded error; greedy argmax should mostly agree
    agree = float(jnp.mean((jnp.argmax(l_fp, -1) == jnp.argmax(l_f8, -1))
                           .astype(jnp.float32)))
    assert agree >= 0.5, agree
    rel = float(jnp.max(jnp.abs(l_fp - l_f8)) / jnp.max(jnp.abs(l_fp)))
    assert rel < 0.2, rel


def test_abstract_quantized_matches_real():
    cfg, params = _setup()
    shapes = jax.eval_shape(lambda: params)
    qa = QS.abstract_quantized(shapes, bits=4)
    qr = QS.quantize_params(params, bits=4)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(qa),
            jax.tree_util.tree_leaves_with_path(qr)):
        assert a.shape == b.shape and a.dtype == b.dtype, (pa, a, b)
