"""Reads artifacts/dryrun/*.json and prints the §Roofline table:
three terms per (arch x shape), dominant bottleneck, MODEL_FLOPS ratio, and
one-line what-would-move-it-down notes."""
from __future__ import annotations

import glob
import json
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

NOTES = {
    ("memory", True): "fp32 score tensors -> flash-attention kernel keeps "
                      "them in VMEM",
    ("memory", False): "weight/optimizer traffic -> int8 weights "
                       "(quant_matmul) / larger microbatch",
    ("collective", True): "FSDP all-gathers -> overlap with layer compute; "
                          "TP->data reshard",
    ("collective", False): "TP all-reduces -> reduce-scatter + local update",
    ("compute", True): "masked-half attention FLOPs -> causal block skipping",
    ("compute", False): "remat recompute -> selective checkpoint policy",
}


def load(mesh="single"):
    rows = []
    for f in sorted(glob.glob(str(ART / f"*__{mesh}.json"))):
        rows.append(json.loads(Path(f).read_text()))
    return rows


PEAK = 197e12


def main(fast: bool = False):
    rows = load()
    print("roofline_table (single-pod 16x16, per chip, from compiled dry-run)")
    print("frac = (MODEL_FLOPS/chips/peak) / t_step  — the roofline-MFU "
          "fraction")
    hdr = (f"{'arch':24s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'dominant':>10s} {'useful':>7s} {'frac':>6s}")
    print(hdr)
    ok = skipped = err = 0
    for r in rows:
        if r["status"] == "skipped":
            skipped += 1
            print(f"{r['arch']:24s} {r['shape']:12s} "
                  f"{'-- skipped: ' + r['reason'][:44]}")
            continue
        if r["status"] != "ok" or "roofline" not in r:
            err += 1
            print(f"{r['arch']:24s} {r['shape']:12s} -- {r['status']}")
            continue
        ok += 1
        ro = r["roofline"]
        frac = (r["model_flops"] / r["chips"] / PEAK) / ro["t_step_s"] \
            if ro["t_step_s"] else 0.0
        print(f"{r['arch']:24s} {r['shape']:12s} {ro['t_compute_s']:9.3g} "
              f"{ro['t_memory_s']:9.3g} {ro['t_collective_s']:9.3g} "
              f"{ro['dominant']:>10s} {r['useful_flops_ratio']:7.2f} "
              f"{frac:6.3f}")
    print(f"cells: ok={ok} skipped={skipped} error={err}")
    return rows


if __name__ == "__main__":
    main()
