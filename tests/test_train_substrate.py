"""Trainer / optimizer / checkpoint / fault-tolerance integration tests."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.ckpt.checkpoint import CheckpointManager
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.dist import fault_tolerance as FT
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   schedule_lr)
from repro.train.trainer import Trainer, TrainerConfig
from repro.train import train_state as TS


def _tiny():
    cfg = ARCHS["qwen3-0.6b"].reduced(vocab_size=64)
    pipe = TokenPipeline(TokenPipelineConfig(
        vocab_size=64, seq_len=16, global_batch=4, seed=0, branching=2))
    opt = AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=40,
                      weight_decay=0.0)
    return cfg, pipe, opt


def test_loss_decreases():
    cfg, pipe, opt = _tiny()
    tr = Trainer(cfg, opt, TrainerConfig(total_steps=25, log_every=5), pipe)
    out = tr.run()
    first = tr.history[0]["loss"]
    assert out["final_loss"] < first - 0.3, (first, out["final_loss"])


def test_grad_accumulation_matches_full_batch():
    cfg, pipe, opt = _tiny()
    opt = dataclasses.replace(opt, grad_clip=1e9)   # clip off for exactness
    key = jax.random.PRNGKey(0)
    state = TS.init_state(key, cfg, opt)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    full = TS.make_train_step(cfg, opt, remat=False)
    micro = TS.make_train_step(cfg, opt, remat=False, microbatch=2)
    s1, m1 = jax.jit(full)(state, batch)
    s2, m2 = jax.jit(micro)(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    l1 = jax.tree_util.tree_leaves(s1.params)
    l2 = jax.tree_util.tree_leaves(s2.params)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-5)


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": (jnp.ones(4), {"c": jnp.zeros((2, 2), jnp.bfloat16)})}
    mgr.save(7, tree, meta={"step": 7})
    out, meta = mgr.restore(like=tree)
    assert meta["step"] == 7
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    tree = {"a": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_trainer_resume_is_seamless(tmp_path):
    cfg, pipe, opt = _tiny()
    # run 1: 12 steps with ckpt every 5
    t1 = Trainer(cfg, opt, TrainerConfig(
        total_steps=12, ckpt_every=5, log_every=1,
        ckpt_dir=str(tmp_path)), pipe)
    t1.run()
    # run 2 (fresh process simulation): resumes from step 11 (final ckpt)
    t2 = Trainer(cfg, opt, TrainerConfig(
        total_steps=16, ckpt_every=5, log_every=1,
        ckpt_dir=str(tmp_path)), pipe)
    state, start = t2.init_or_resume(jax.random.PRNGKey(0))
    assert start == 12
    out = t2.run()
    assert out["last_step"] == 15
    # uninterrupted reference must match the resumed loss trajectory
    t3 = Trainer(cfg, opt, TrainerConfig(total_steps=16, log_every=1), pipe)
    out3 = t3.run()
    resumed_tail = {r["step"]: r["loss"] for r in t2.history}
    ref_tail = {r["step"]: r["loss"] for r in t3.history}
    for s in range(12, 16):
        np.testing.assert_allclose(resumed_tail[s], ref_tail[s], rtol=2e-3)


def test_preemption_checkpoints_and_stops(tmp_path):
    cfg, pipe, opt = _tiny()
    tr = Trainer(cfg, opt, TrainerConfig(
        total_steps=50, ckpt_every=1000, log_every=1,
        ckpt_dir=str(tmp_path)), pipe)
    orig = tr.step_fn

    def step_and_preempt(state, batch):
        tr.request_preemption()
        return orig(state, batch)
    tr.step_fn = step_and_preempt
    out = tr.run()
    assert out["preempted"] and out["last_step"] == 0
    assert tr.ckpt.latest_step() == 0


def test_schedule_shapes():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(schedule_lr(cfg, jnp.asarray(s))) for s in range(0, 100, 5)]
    assert lrs[0] == 0.0
    assert abs(max(lrs) - 1.0) < 1e-6
    assert lrs[-1] >= 0.1 - 1e-6 and lrs[-1] < 0.3


def test_straggler_redistribution():
    mask = FT.deadline_barrier([0.1, 0.1, 9.9, 0.2], deadline_s=1.0)
    assert mask == [True, True, False, True]
    deal = FT.redistribute_batch(256, mask)
    assert deal[2] == 0 and sum(deal.values()) == 256
    assert all(v > 0 for h, v in deal.items() if h != 2)


def test_data_pipeline_deterministic_and_elastic():
    pcfg = TokenPipelineConfig(vocab_size=97, seq_len=12, global_batch=8,
                               seed=3)
    p = TokenPipeline(pcfg)
    a = p.batch_at(5)["tokens"]
    b = p.batch_at(5)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = p.batch_at(6)["tokens"]
    assert not np.array_equal(a, c)
    # elastic host split covers the global batch disjointly by shape
    h0 = p.batch_at(5, host_id=0, n_hosts=2)["tokens"]
    h1 = p.batch_at(5, host_id=1, n_hosts=2)["tokens"]
    assert h0.shape == (4, 12) and h1.shape == (4, 12)
    assert not np.array_equal(h0, h1)
