"""Pure-jnp oracle for block_sparse_matmul."""
import jax.numpy as jnp


def block_sparse_matmul_ref(x, w, block_mask, *, block_k: int, block_n: int):
    mask = jnp.repeat(jnp.repeat(block_mask.astype(w.dtype), block_k, 0),
                      block_n, 1)
    return (x.astype(jnp.float32) @ (w * mask).astype(jnp.float32)
            ).astype(x.dtype)
