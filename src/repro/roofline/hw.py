"""Target hardware constants (TPU v5e, per chip) — given by the assignment."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HWSpec:
    name: str
    peak_flops: float          # bf16 FLOP/s per chip
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per ICI link
    ici_links: int             # usable links per chip (2D torus: 4)
    hbm_bytes: float           # HBM capacity per chip
    vmem_bytes: float


TPU_V5E = HWSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    ici_links=4,
    hbm_bytes=16e9,
    vmem_bytes=128 * 2 ** 20,
)
