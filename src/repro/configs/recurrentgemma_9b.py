"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2 rec : 1 local.
[arXiv:2402.19427 (Griffin); unverified]"""
from repro.configs.base import ArchConfig, LayerSpec, RGLRUConfig, Segment

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    d_model=4096,
    vocab_size=256000,
    # 38 layers = (rec, rec, local) x 12 + (rec, rec)
    segments=(
        Segment((LayerSpec("rec", "dense"), LayerSpec("rec", "dense"),
                 LayerSpec("local", "dense")), 12),
        Segment((LayerSpec("rec", "dense"), LayerSpec("rec", "dense")), 1),
    ),
    num_heads=16,
    num_kv_heads=1,                    # MQA on the local-attention layers
    head_dim=256,
    d_ff=12288,
    mlp_type="geglu",
    window_size=2048,
    rglru=RGLRUConfig(lru_width=4096, d_conv=4, c_exponent=8.0),
    norm_unit_offset=True,
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10000.0,
    source="arXiv:2402.19427; unverified",
    notes="sub-quadratic: RG-LRU state + O(window) ring cache -> long_500k runs",
)
