"""Distributed-training substrate: sharding rules, compressed gradient
all-reduce, and fault-tolerance helpers.

Everything here is mesh-shape agnostic — rules resolve on abstract shapes
(ShapeDtypeStructs against an ``AbstractMesh``), so they are unit-testable
without devices and reusable from 1 chip to a pod.
"""
