"""Pruning (paper §II-B).

Unstructured magnitude pruning is the paper's hardware winner: bespoke
circuits delete the multiplier of every zero weight outright and shrink the
neuron's adder tree. We implement:

* unstructured per-layer magnitude masks at a target sparsity,
* global (cross-layer) magnitude pruning,
* structured neuron (column) pruning for comparison,
* a cubic sparsity ramp schedule for prune-during-training,
* mask application with STE-style gradient masking (pruned weights get no
  gradient so fine-tuning does not resurrect them).

TPU adaptation (DESIGN.md §3): block-structured masks (``block_mask``) are
the MXU-meaningful unit — consumed by ``kernels/block_sparse_matmul``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def magnitude_mask(w: jnp.ndarray, sparsity: float) -> jnp.ndarray:
    """Keep the largest-|w| (1-sparsity) fraction. Returns bool mask."""
    assert 0.0 <= sparsity < 1.0
    if sparsity == 0.0:
        return jnp.ones_like(w, dtype=bool)
    k = int(round(w.size * (1.0 - sparsity)))
    k = max(k, 1)
    thresh = jnp.sort(jnp.abs(w).reshape(-1))[-k]
    return jnp.abs(w) >= thresh


def global_magnitude_masks(params, sparsity: float, *, min_size: int = 16):
    """One global threshold across all >=min_size leaves (Deep Compression
    style). Small leaves (biases, norms) are never pruned."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    big = [jnp.abs(l).reshape(-1) for l in leaves
           if l.size >= min_size and l.ndim >= 2]
    allw = jnp.sort(jnp.concatenate(big))
    k = max(int(round(allw.size * (1.0 - sparsity))), 1)
    thresh = allw[-k]
    masks = [jnp.abs(l) >= thresh if (l.size >= min_size and l.ndim >= 2)
             else jnp.ones_like(l, dtype=bool) for l in leaves]
    return jax.tree_util.tree_unflatten(treedef, masks)


def neuron_mask(w: jnp.ndarray, sparsity: float) -> jnp.ndarray:
    """Structured: prune whole output columns by L2 norm."""
    norms = jnp.linalg.norm(w, axis=0)
    k = max(int(round(norms.size * (1.0 - sparsity))), 1)
    thresh = jnp.sort(norms)[-k]
    return jnp.broadcast_to(norms >= thresh, w.shape)


def block_mask(w: jnp.ndarray, sparsity: float, block=(16, 16)) -> jnp.ndarray:
    """TPU-structured: prune (bk, bn) tiles by Frobenius norm. w must be 2D
    with dims divisible by the block (callers pad)."""
    K, N = w.shape
    bk, bn = block
    assert K % bk == 0 and N % bn == 0, (w.shape, block)
    tiles = w.reshape(K // bk, bk, N // bn, bn)
    norms = jnp.sqrt(jnp.sum(jnp.square(tiles), axis=(1, 3)))   # (K/bk, N/bn)
    k = max(int(round(norms.size * (1.0 - sparsity))), 1)
    thresh = jnp.sort(norms.reshape(-1))[-k]
    keep = norms >= thresh
    return jnp.repeat(jnp.repeat(keep, bk, axis=0), bn, axis=1)


def apply_mask(w: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Masked weight with masked gradient (pruned entries stay dead)."""
    return w * mask.astype(w.dtype)


def apply_masks(params, masks):
    return jax.tree_util.tree_map(apply_mask, params, masks)


def sparsity_of(masks) -> float:
    tot = sum(int(m.size) for m in jax.tree_util.tree_leaves(masks))
    kept = sum(int(jnp.sum(m)) for m in jax.tree_util.tree_leaves(masks))
    return 1.0 - kept / max(tot, 1)


def cubic_schedule(step: int, *, begin: int, end: int, final: float,
                   initial: float = 0.0) -> float:
    """Zhu & Gupta (2017) cubic sparsity ramp for prune-during-training."""
    if step <= begin:
        return initial
    if step >= end:
        return final
    t = (step - begin) / max(end - begin, 1)
    return final + (initial - final) * (1.0 - t) ** 3
