"""Kernel micro-bench: CPU-interpret correctness timing (sanity) + DERIVED
TPU roofline per kernel — HBM bytes and FLOPs are computed analytically from
the kernel's block schedule (what the dry-run does for whole models). This is
the per-kernel evidence that the paper's three techniques cut the
memory-roofline term (DESIGN.md §3).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.hw import TPU_V5E


def derived_roofline(M, K, N, *, weight_bytes_per_elem, extra_bytes=0.0,
                     keep=1.0):
    """One (M,K)@(K,N) matmul: activation + weight + output HBM bytes vs
    MXU flops, v5e ridge point comparison."""
    flops = 2.0 * M * K * N * keep
    bytes_ = (M * K * 2.0                       # x bf16
              + K * N * weight_bytes_per_elem * keep
              + M * N * 2.0 + extra_bytes)
    t_c = flops / TPU_V5E.peak_flops
    t_m = bytes_ / TPU_V5E.hbm_bw
    return {"flops": flops, "bytes": bytes_, "t_compute": t_c,
            "t_memory": t_m, "bound": "compute" if t_c > t_m else "memory",
            "t": max(t_c, t_m)}


def run():
    # decode-shaped GEMM: small M (batch), big K,N (the memory-bound regime
    # the paper's techniques target)
    M, K, N = 16, 4096, 14336
    rows = []
    dense = derived_roofline(M, K, N, weight_bytes_per_elem=2.0)
    rows.append(("dense_bf16", dense, 1.0))
    q8 = derived_roofline(M, K, N, weight_bytes_per_elem=1.0,
                          extra_bytes=N * 2)
    rows.append(("quant_int8", q8, dense["t"] / q8["t"]))
    q4 = derived_roofline(M, K, N, weight_bytes_per_elem=0.5,
                          extra_bytes=N * 2)
    rows.append(("quant_int4", q4, dense["t"] / q4["t"]))
    cl16 = derived_roofline(M, K, N, weight_bytes_per_elem=1.0,
                            extra_bytes=K * 16 * 2)
    rows.append(("clustered_k16_idx8", cl16, dense["t"] / cl16["t"]))
    bs50 = derived_roofline(M, K, N, weight_bytes_per_elem=2.0, keep=0.5)
    rows.append(("block_sparse_50", bs50, dense["t"] / bs50["t"]))

    # flash attention bytes: dense scores vs VMEM-resident
    B, H, T, hd = 8, 32, 4096, 128
    score_bytes = B * H * T * T * 4.0
    qkv = 3 * B * T * H * hd * 2.0 + B * T * H * hd * 2.0
    t_dense = (score_bytes * 2 + qkv) / TPU_V5E.hbm_bw
    t_flash = qkv / TPU_V5E.hbm_bw
    rows.append(("attn_dense_scores",
                 {"bytes": score_bytes * 2 + qkv, "t": t_dense,
                  "bound": "memory"}, 1.0))
    rows.append(("flash_attention",
                 {"bytes": qkv, "t": t_flash, "bound": "memory"},
                 t_dense / t_flash))
    return rows


def interpret_sanity():
    """CPU interpret-mode wall times (not perf — correctness-path latency)."""
    from repro.kernels.quant_matmul import quant_matmul
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (128, 256), jnp.float32)
    wq = jax.random.randint(k, (256, 128), -127, 128, jnp.int8)
    s = jnp.ones((128,), jnp.float32) * 0.01
    y = quant_matmul(x, wq, s)  # compile
    t0 = time.time()
    for _ in range(3):
        quant_matmul(x, wq, s).block_until_ready()
    return (time.time() - t0) / 3 * 1e6


def hw_pricing_bench(population: int = 64, reps: int = 20):
    """Printed-area pricing throughput: the GA's cost callback. Compares the
    retired scalar path (np.vectorize CSD per coefficient) against the
    vectorized bit-twiddling + one-call population pricing (hw_model)."""
    from repro.core import hw_model as HW
    rng = np.random.default_rng(0)
    q1 = rng.integers(-127, 128, (population, 11, 10))
    q2 = rng.integers(-127, 128, (population, 10, 7))

    t0 = time.time()
    for _ in range(reps):
        csd = np.vectorize(HW.csd_nonzero_digits, otypes=[np.int64])
        for p in range(population):
            csd(q1[p]), csd(q2[p])
    t_scalar = (time.time() - t0) / reps

    t0 = time.time()
    for _ in range(reps):
        HW.mlp_cost_batch([q1, q2], w_bits=[np.full(population, 8)] * 2)
    t_vec = (time.time() - t0) / reps
    return t_scalar, t_vec


def main(fast: bool = False):
    rows = run()
    print("kernel_bench (derived v5e roofline, decode-shaped workloads)")
    print(f"{'kernel':22s} {'GB moved':>9s} {'bound':>8s} {'t_us':>9s} "
          f"{'speedup':>8s}")
    for name, r, sp in rows:
        print(f"{name:22s} {r['bytes']/1e9:9.3f} {r['bound']:>8s} "
              f"{r['t']*1e6:9.1f} {sp:8.2f}x")
    us = interpret_sanity()
    print(f"interpret-mode sanity: quant_matmul {us:.0f} us/call (CPU, "
          f"correctness path only)")
    t_scalar, t_vec = hw_pricing_bench()
    print(f"printed-area pricing, population=64: scalar CSD "
          f"{t_scalar*1e3:.1f} ms -> vectorized {t_vec*1e3:.2f} ms "
          f"({t_scalar/t_vec:.0f}x)")
    return rows


if __name__ == "__main__":
    main()
