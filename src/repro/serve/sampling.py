"""Sampling strategies for the serving engine (greedy is the engine default;
these are the stochastic options)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits):
    """logits (B, V) -> (B,) int32."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(key, logits, t: float = 1.0):
    if t <= 0:
        return greedy(logits)
    return jax.random.categorical(key, logits / t, axis=-1).astype(jnp.int32)


def top_k(key, logits, k: int = 40, t: float = 1.0):
    """Sample from the k highest logits."""
    v, _ = jax.lax.top_k(logits, k)
    cutoff = v[..., -1:]
    masked = jnp.where(logits < cutoff, -jnp.inf, logits)
    return temperature(key, masked, t)


def top_p(key, logits, p: float = 0.9, t: float = 1.0):
    """Nucleus sampling: smallest prefix of the sorted distribution with
    cumulative probability >= p."""
    probs = jax.nn.softmax(logits / max(t, 1e-6), axis=-1)
    sorted_probs = jnp.sort(probs, axis=-1)[..., ::-1]
    cum = jnp.cumsum(sorted_probs, axis=-1)
    # number of tokens kept per row
    keep = jnp.sum(cum < p, axis=-1, keepdims=True) + 1
    thresh = jnp.take_along_axis(sorted_probs, keep - 1, axis=-1)
    masked = jnp.where(probs < thresh, -jnp.inf, logits)
    return temperature(key, masked, 1.0)
