"""Bounded list-like event log (the fix for unbounded in-memory growth).

`search.islands.IslandFleet` used plain lists for fleet events and
quarantine records: on an hours-long run with a chatty fault schedule they
grow without bound. :class:`RingLog` keeps only the newest ``cap`` items in
memory while counting everything (``total``/``dropped``), and optionally
*spills* every appended item to the obs trace — the JSONL is the complete
stream, the ring is the working set.

It is deliberately list-shaped: ``append``/``extend``/iteration/``len``/
indexing and full-slice assignment (``log[:] = items`` — the
checkpoint-restore idiom in `search.runtime`) all work, so existing
callers and tests that treated the field as a list keep working.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Iterable, List, Optional


class RingLog:
    def __init__(self, cap: int = 1024, *,
                 spill: Optional[Callable[[object], None]] = None):
        if cap <= 0:
            raise ValueError("RingLog cap must be positive")
        self.cap = cap
        self._d: deque = deque(maxlen=cap)
        self.total = 0                      # everything ever appended
        self._spill = spill

    @property
    def dropped(self) -> int:
        return self.total - len(self._d)

    def append(self, item) -> None:
        self.total += 1
        if self._spill is not None:
            self._spill(item)
        self._d.append(item)

    def extend(self, items: Iterable) -> None:
        for it in items:
            self.append(it)

    def clear(self) -> None:
        self._d.clear()
        self.total = 0

    # -- list compatibility --------------------------------------------------

    def __len__(self) -> int:
        return len(self._d)

    def __iter__(self):
        return iter(self._d)

    def __bool__(self) -> bool:
        return bool(self._d)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return list(self._d)[i]
        return self._d[i]

    def __setitem__(self, key, value) -> None:
        """Only full-slice replacement (``log[:] = items``) is supported —
        the restore idiom. Restored items bypass the spill (they were
        spilled when first appended) and reset ``total`` to the restored
        length; `search.runtime` re-applies the checkpointed total."""
        if not (isinstance(key, slice) and key.start is None
                and key.stop is None and key.step is None):
            raise TypeError("RingLog only supports full-slice assignment")
        self._d.clear()
        self._d.extend(list(value)[-self.cap:])
        self.total = len(self._d)

    def __repr__(self) -> str:
        return (f"RingLog(cap={self.cap}, kept={len(self._d)}, "
                f"total={self.total})")

    def to_list(self) -> List:
        return list(self._d)


__all__ = ["RingLog"]
