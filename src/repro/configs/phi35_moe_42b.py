"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct]"""
from repro.configs.base import ArchConfig, LayerSpec, MoEConfig, Segment

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    d_model=4096,
    vocab_size=32064,
    segments=(Segment((LayerSpec("attn", "moe"),), 32),),
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    mlp_type="swiglu",
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=6400,
                  capacity_factor=1.25),
    rope_theta=10000.0,
    source="hf:microsoft/Phi-3.5-MoE-instruct; hf",
)
