"""The paper's own printed-MLP classifier configs (Mubarik et al. MICRO'20
baselines). Topologies follow the MICRO'20 bespoke classifiers: a single
small hidden layer sized per dataset.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class PrintedMLPConfig:
    name: str
    n_features: int
    n_classes: int
    hidden: Tuple[int, ...]
    # baseline bespoke fixed-point precision (MICRO'20 uses 8-bit coefficients)
    baseline_bits: int = 8
    input_bits: int = 8

    @property
    def layer_dims(self) -> Tuple[int, ...]:
        return (self.n_features,) + self.hidden + (self.n_classes,)


WHITEWINE = PrintedMLPConfig("whitewine", 11, 7, (10,))
REDWINE = PrintedMLPConfig("redwine", 11, 6, (10,))
PENDIGITS = PrintedMLPConfig("pendigits", 16, 10, (20,))
SEEDS = PrintedMLPConfig("seeds", 7, 3, (8,))

PRINTED_MLPS = {c.name: c for c in (WHITEWINE, REDWINE, PENDIGITS, SEEDS)}
