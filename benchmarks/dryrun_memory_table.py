"""§Dry-run memory table: per-chip argument/temp/alias bytes for every
compiled cell (both meshes), with a 16 GB HBM fit verdict on the
weight-resident portion (args − aliased-state)."""
from __future__ import annotations

import glob
import json
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
HBM = 16 * 2 ** 30


def main(fast: bool = False):
    rows = []
    for f in sorted(glob.glob(str(ART / "*.json"))):
        r = json.loads(Path(f).read_text())
        if r.get("status") != "ok" or "memory" not in r:
            continue
        name = Path(f).stem
        m = r["memory"]
        rows.append((name, m["argument_bytes"], m["temp_bytes"],
                     m["alias_bytes"]))
    print("dryrun_memory_table (per chip; temp is an XLA:CPU upper bound)")
    print(f"{'cell':58s} {'args GiB':>9s} {'temp GiB':>9s} {'alias GiB':>10s} "
          f"{'resident<=16G':>13s}")
    fit = nofit = 0
    for name, a, t, al in rows:
        # donated outputs alias their inputs, so `args` counts the resident
        # params + persistent state exactly once
        resident = a
        ok = resident <= HBM
        fit += ok
        nofit += not ok
        print(f"{name:58s} {a/2**30:9.2f} {t/2**30:9.2f} {al/2**30:10.2f} "
              f"{'yes' if ok else 'NO':>13s}")
    print(f"cells: fit={fit} over-budget={nofit} (over-budget cells document "
          f"their remedy in EXPERIMENTS.md §Dry-run)")
    return rows


if __name__ == "__main__":
    main()
