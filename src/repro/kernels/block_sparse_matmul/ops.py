"""jit'd wrapper (shapes must already be block multiples — pruning masks are
built on padded weights by `repro.core.pruning.block_mask`)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.block_sparse_matmul.kernel import block_sparse_matmul_pallas
from repro.kernels.block_sparse_matmul.ref import block_sparse_matmul_ref
from repro.obs import prof as PF
from repro.obs import trace as TR


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def _block_sparse_matmul_jit(x, w, block_mask, *, block_m, block_n, block_k,
                             interpret):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return block_sparse_matmul_pallas(
        x, w, block_mask, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret)


def block_sparse_matmul(x, w, block_mask, *, block_m=128, block_n=128,
                        block_k=128, interpret: bool | None = None):
    if not TR.active():
        return _block_sparse_matmul_jit(x, w, block_mask, block_m=block_m,
                                        block_n=block_n, block_k=block_k,
                                        interpret=interpret)
    key = ("block_sparse_matmul", x.shape, w.shape, block_m, block_n, block_k)
    with PF.dispatch("kernels.block_sparse_matmul", key,
                     lower=lambda: _block_sparse_matmul_jit.lower(
                         x, w, block_mask, block_m=block_m, block_n=block_n,
                         block_k=block_k, interpret=interpret),
                     m=x.shape[0], k=x.shape[1], n=w.shape[1]):
        y = _block_sparse_matmul_jit(x, w, block_mask, block_m=block_m,
                                     block_n=block_n, block_k=block_k,
                                     interpret=interpret)
        jax.block_until_ready(y)
    return y


__all__ = ["block_sparse_matmul", "block_sparse_matmul_ref"]
