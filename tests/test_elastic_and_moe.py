"""Elastic checkpoint restore + MoE dispatch invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import ARCHS
from repro.nn import moe as M
from repro.nn import transformer as T


def test_elastic_restore_onto_new_sharding(tmp_path):
    """Checkpoint saved without mesh context restores onto an explicit
    NamedSharding (the elastic path: new mesh shape at resume)."""
    mgr = CheckpointManager(tmp_path, async_write=False)
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "b": jnp.ones(8)}
    mgr.save(3, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None)),
          "b": NamedSharding(mesh, P())}
    out, _ = mgr.restore(like=tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert out["w"].sharding == sh["w"]


def test_async_checkpoint_eventually_lands(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=True)
    tree = {"w": jnp.ones((128, 128))}
    mgr.save(1, tree)
    mgr.save(2, tree)
    mgr.wait()
    assert mgr.latest_step() == 2


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------


def _moe_cfg(dispatch="global", cf=1.25, E=4, k=2):
    base = ARCHS["phi3.5-moe-42b-a6.6b"].reduced()
    return dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, num_experts=E, top_k=k,
                                      capacity_factor=cf, dispatch=dispatch))


@pytest.mark.parametrize("dispatch", ["global", "per_sample"])
def test_moe_output_finite_and_grad_flows(dispatch):
    cfg = _moe_cfg(dispatch)
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))

    def loss(p):
        y, aux = M.moe_apply(p, x, cfg)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    # router must receive gradient (its weights steer the mixture)
    assert float(jnp.max(jnp.abs(g["router"]["kernel"]))) > 0


def test_moe_capacity_drops_tokens_not_crash():
    """cf=0.25 forces drops; output stays finite and bounded."""
    cfg = _moe_cfg(cf=0.25)
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, aux = M.moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_aux_loss_penalizes_imbalance():
    """A router collapsed onto one expert must have higher aux loss than a
    well-spread router."""
    cfg = _moe_cfg(E=4, k=1)
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    _, aux_balanced = M.moe_apply(p, x, cfg)
    p_collapsed = jax.tree_util.tree_map(lambda v: v, p)
    k2 = p["router"]["kernel"].at[:, 0].set(100.0)
    p_collapsed["router"]["kernel"] = k2
    _, aux_collapsed = M.moe_apply(p_collapsed, x, cfg)
    assert float(aux_collapsed) > float(aux_balanced)


def test_moe_respects_topk_sparsity():
    """With orthogonal expert outputs, each token's output must lie in the
    span of at most top_k experts + shared. Proxy check: zeroing the weights
    of unused experts does not change a token routed elsewhere."""
    cfg = _moe_cfg(E=4, k=1, cf=8.0)
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 8, cfg.d_model))
    logits = x.reshape(-1, cfg.d_model) @ p["router"]["kernel"]
    top1 = np.asarray(jnp.argmax(logits, -1))
    unused = [e for e in range(4) if e not in set(top1.tolist())]
    if not unused:
        pytest.skip("all experts used by chance")
    y1, _ = M.moe_apply(p, x, cfg)
    p2 = jax.tree_util.tree_map(lambda v: v, p)
    for name in ("wi_gate", "wi_up", "wo"):
        p2["experts"][name] = p["experts"][name].at[unused[0]].set(0.0)
    y2, _ = M.moe_apply(p2, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)
