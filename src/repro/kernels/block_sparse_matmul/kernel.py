"""Block-sparse matmul: zero (bk, bn) weight tiles are skipped via pl.when.

The paper's unstructured pruning adapted to the MXU (DESIGN.md §3): scalar
zeros can't be skipped by a systolic array, but a zeroed VMEM *tile* can —
both its HBM fetch and its MXU issue are guarded by the block mask. FLOPs
and weight bytes scale with (1 - block_sparsity), matching the bespoke
circuit's deleted-multiplier semantics at tile granularity.

Note on the HBM fetch: with standard BlockSpec prefetch the w tile is still
DMA'd; a production version uses scalar-prefetch grid remapping to also skip
the DMA (documented EXPERIMENTS.md §Perf) — the MXU-skip is what pl.when
delivers portably.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams


def _bsmm_kernel(mask_ref, x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[0, 0] > 0)
    def _compute():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def block_sparse_matmul_pallas(x, w, block_mask, *, block_m: int = 128,
                               block_n: int = 128, block_k: int = 128,
                               interpret: bool = False):
    """x: (M, K); w: (K, N); block_mask: (K//bk, N//bn) int32 (1 = live)."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    assert block_mask.shape == (K // block_k, N // block_n)
    k_steps = K // block_k
    grid = (M // block_m, N // block_n, k_steps)

    return pl.pallas_call(
        functools.partial(_bsmm_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_mask.astype(jnp.int32), x, w)
