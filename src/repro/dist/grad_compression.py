"""int8 gradient compression with error feedback (EF-SGD style).

Cross-host gradient all-reduce is the bandwidth bottleneck of data-parallel
training at pod scale; 8-bit symmetric quantization cuts the wire bytes 4x
vs fp32 (2x vs bf16). The quantization residual is carried in an error-
feedback state and re-injected next step, so the *sum over steps* of what
was transmitted tracks the sum of true gradients (unbiased in the EF sense)
and convergence is unaffected at these bit widths.

`make_compressed_allreduce` returns a pure function usable both inside a
`shard_map`/`pmap` body (where the mesh axis is live and `lax.pmean`
averages across hosts) and in single-controller replicated execution (where
the mean of identical replicated contributions is the contribution itself).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_leaf(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-leaf int8 quantization. -> (q int8, scale fp32) with
    g ~= q * scale and |g - q*scale| <= scale/2 elementwise."""
    amax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    scale = (amax / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_leaf(q: jnp.ndarray, scale, dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_error_state(grads):
    """Zero EF residual matching the gradient pytree."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(jnp.shape(g), jnp.float32), grads)


def compress_tree(grads, err_state):
    """One EF compression round. Returns (sent, new_err): `sent` is the
    dequantized int8 payload actually transmitted, `new_err` the residual
    to carry into the next step."""
    def leaf(g, e):
        carried = g.astype(jnp.float32) + e
        q, s = quantize_leaf(carried)
        sent = dequantize_leaf(q, s)
        return sent, carried - sent

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    pairs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    sent = jax.tree_util.tree_unflatten(treedef, [p[0] for p in pairs])
    new_err = jax.tree_util.tree_unflatten(treedef, [p[1] for p in pairs])
    return sent, new_err


def make_compressed_allreduce(mesh, axis_name: str):
    """-> allreduce(grads, err_state) -> (mean_grads, new_err_state).

    Inside a mapped context the live `axis_name` averages the compressed
    payloads across hosts; outside one (replicated single-controller), the
    all-reduce of identical contributions is the identity, so the payload
    itself is returned.
    """
    assert axis_name in dict(mesh.shape), (axis_name, mesh)

    def allreduce(grads, err_state):
        sent, new_err = compress_tree(grads, err_state)
        try:
            mean = jax.tree_util.tree_map(
                lambda s: jax.lax.pmean(s, axis_name), sent)
        except NameError:      # axis not live: replicated execution
            mean = sent
        return mean, new_err

    return allreduce
