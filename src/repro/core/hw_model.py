"""Analytic bespoke printed-circuit area/power model (simulated EGT flow).

The paper prices designs with Synopsys DC + PrimeTime + the EGT
(Electrolyte-Gated Transistor) library. Those tools are unavailable here, so
this module implements the published *structure* of bespoke-MLP cost
analytically (Mubarik MICRO'20; Armeniakos DATE'22):

* a bespoke constant-coefficient multiplier is a shift-add network whose size
  is (#non-zero CSD digits of the coefficient - 1) adders at (input_bits +
  weight_bits) width — a zero coefficient is free (no multiplier printed),
  a power-of-two coefficient is a wire shift;
* each neuron sums its non-zero products through an adder tree: (operands-1)
  adders at accumulator width; pruning removes operands, shrinking the tree;
* per-input weight clustering shares the product x_i*c across fan-out: the
  row's multiplier count collapses to its #distinct non-zero clusters
  (adder trees are unchanged — sharing saves multipliers, not sums);
* ReLU = comparator+mux, argmax = comparator tree.

Unit calibration: EGT full-adder equivalents. AREA_FA/POWER_FA are set so the
un-minimized 8-bit bespoke MLPs land in the tens-of-cm^2 / ~100 mW range
reported by MICRO'20. Absolute numbers are approximate (documented DESIGN.md
§4); the paper's *relative* claims (5x/2.8x/3.5x/8x) are what EXPERIMENTS.md
validates.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# EGT-scale constants, calibrated so (a) un-minimized 8-bit bespoke MLPs land
# at the tens-of-cm^2 / tens-of-mW magnitudes of MICRO'20 and (b) the
# multiplier/adder area split matches bespoke synthesis (multipliers ~3/4 of
# neuron area -- Armeniakos DATE'22 Fig.3): see EXPERIMENTS.md §Calibration.
AREA_FA_MM2 = 0.60          # printed 1-bit full adder, mm^2
POWER_FA_MW = 0.004         # mW per full-adder equivalent (EGT, ~few Hz duty)
RELU_FA_EQ = 2.0            # comparator+mux per output bit, FA equivalents
ARGMAX_FA_EQ = 1.2          # comparator per bit, FA equivalents
MULT_ROUTING_FACTOR = 2.0   # partial-product generation + shift routing
# overhead per CSD-digit adder: bespoke multipliers dominate printed neuron
# area (~75-85%, Armeniakos DATE'22) -- this factor sets that split


def csd_nonzero_digits(c: int) -> int:
    """Number of non-zero digits in the canonical signed-digit form of |c|.
    This is the count of shift-add/sub terms a bespoke constant multiplier
    needs (Avizienis recoding)."""
    c = abs(int(c))
    count = 0
    while c:
        if c & 1:
            count += 1
            # CSD: runs of 1s become +/- pair -> round to nearest multiple of 4
            c = c + 1 if (c & 3) == 3 else c - 1
        c >>= 1
    return count


def csd_digits(c: int) -> List[Tuple[int, int]]:
    """Full signed-digit recoding of ``c``: [(shift, sign)] with sign in
    {+1, -1} and c == sum(sign << shift). Same Avizienis recurrence as
    `csd_nonzero_digits` — ``len(csd_digits(c)) == csd_nonzero_digits(c)``
    for every c — but keeping the digits, which is what the bespoke circuit
    compiler (`repro.circuit.compile`) materializes as one shift-add
    network per constant coefficient."""
    neg = c < 0
    c = abs(int(c))
    out: List[Tuple[int, int]] = []
    pos = 0
    while c:
        if c & 1:
            if (c & 3) == 3:
                out.append((pos, -1))
                c += 1
            else:
                out.append((pos, 1))
                c -= 1
        c >>= 1
        pos += 1
    return [(p, -s) for p, s in out] if neg else out


def csd_nonzero_digits_vec(q: np.ndarray) -> np.ndarray:
    """Vectorized `csd_nonzero_digits` over an integer tensor of any shape —
    the same Avizienis recoding run on all coefficients at once with array
    bit-twiddling (one pass per bit position instead of one Python call per
    coefficient). Exact integer arithmetic; matches the scalar loop
    bit-for-bit for every |c| < 2**62."""
    c = np.abs(np.asarray(q, np.int64))
    count = np.zeros(c.shape, np.int64)
    while c.any():
        odd = (c & 1) == 1
        count += odd
        run = odd & ((c & 3) == 3)          # mid-run of 1s -> +1 (borrow up)
        c = np.where(run, c + 1, np.where(odd, c - 1, c))
        c >>= 1
    return count


_csd_vec = csd_nonzero_digits_vec


def _used_clusters(idx: np.ndarray, active: np.ndarray, k: int) -> np.ndarray:
    """Which cluster slots each input row actually drives: idx/active
    (..., d_in, d_out) -> bool (..., d_in, k). A slot counts only if some
    surviving (non-pruned) weight references it — one-hot reduction over the
    fan-out axis, no per-row Python loop."""
    onehot = idx[..., None] == np.arange(k, dtype=idx.dtype)
    return np.logical_and(onehot, active[..., None]).any(axis=-2)


@dataclasses.dataclass
class LayerCost:
    n_multipliers: int
    mult_fa: float
    adder_fa: float
    act_fa: float

    @property
    def total_fa(self) -> float:
        return self.mult_fa + self.adder_fa + self.act_fa


@dataclasses.dataclass
class CircuitCost:
    layers: List[LayerCost]
    argmax_fa: float

    @property
    def total_fa(self) -> float:
        return sum(l.total_fa for l in self.layers) + self.argmax_fa

    @property
    def area_mm2(self) -> float:
        return self.total_fa * AREA_FA_MM2

    @property
    def power_mw(self) -> float:
        return self.total_fa * POWER_FA_MW

    @property
    def n_multipliers(self) -> int:
        return sum(l.n_multipliers for l in self.layers)


def layer_cost(q: np.ndarray, *, w_bits: int, in_bits: int,
               cluster_idx: Optional[np.ndarray] = None,
               cluster_codebook_q: Optional[np.ndarray] = None,
               relu: bool = True) -> LayerCost:
    """Cost of one bespoke dense layer.

    q: integer weight matrix (d_in, d_out) on the w_bits grid (0 = pruned).
    cluster_idx/codebook_q: per-input clustering (idx (d_in,d_out),
    integer codebooks (d_in, k)) — multipliers are shared within a row.
    """
    q = np.asarray(q, np.int64)
    d_in, d_out = q.shape
    prod_width = in_bits + w_bits

    # ---- multipliers -------------------------------------------------------
    # each non-zero CSD digit costs one shifted add/sub at product width
    # (the first partial product's routing/shift network included -- a
    # power-of-two coefficient is wiring, not free)
    active = np.abs(q) > 0                                 # (d_in, d_out)
    if cluster_idx is not None:
        cb = np.asarray(cluster_codebook_q, np.int64)
        sel = _used_clusters(np.asarray(cluster_idx), active, cb.shape[1])
        sel &= np.abs(cb) > 0                              # (d_in, k)
        n_mult = int(sel.sum())
        mult_fa = float((_csd_vec(cb) * sel).sum()
                        * prod_width) * MULT_ROUTING_FACTOR
    else:
        n_mult = int(active.sum())
        mult_fa = float(_csd_vec(q).sum()                  # csd(0) == 0
                        * prod_width) * MULT_ROUTING_FACTOR

    # ---- adder trees (per output neuron; sharing does not shrink sums).
    # Tree adders are dominated by the narrow lower levels: width ~ product
    # width (the few wide top-level adders are amortized).
    operands = active.sum(axis=0)                          # (d_out,)
    adder_fa = float((np.maximum(operands - 1, 0) + 1).sum()
                     * prod_width)                          # tree + bias add

    # ---- activation ---------------------------------------------------------
    acc_w = prod_width + math.ceil(math.log2(max(int(operands.max(initial=1)), 2)))
    act_fa = d_out * RELU_FA_EQ * acc_w if relu else 0.0

    return LayerCost(n_multipliers=n_mult, mult_fa=mult_fa,
                     adder_fa=adder_fa, act_fa=act_fa)


def _ceil_log2(m: np.ndarray) -> np.ndarray:
    """Exact integer ceil(log2(m)) for int arrays m >= 1 (frexp exponent of
    m-1 — no float-log rounding)."""
    m = np.asarray(m, np.int64)
    return np.frexp((m - 1).astype(np.float64))[1].astype(np.int64)


def layer_cost_batch(q: np.ndarray, *, w_bits: np.ndarray, in_bits,
                     cluster_idx: Optional[np.ndarray] = None,
                     cluster_codebook_q: Optional[np.ndarray] = None,
                     has_cluster: Optional[np.ndarray] = None,
                     relu: bool = True) -> Dict[str, np.ndarray]:
    """Population-vectorized `layer_cost`: price one layer for P candidates
    in one pass. Matches the scalar path exactly (all intermediates are
    integer until the final FA-equivalent scaling).

    q:            (P, d_in, d_out) integer weights (0 = pruned)
    w_bits:       (P,) per-candidate weight bits (or scalar)
    in_bits:      (P,) per-candidate input bits (or scalar)
    cluster_idx:  (P, d_in, d_out) cluster assignments (padded slots unused)
    cluster_codebook_q: (P, d_in, k_max) integer codebooks
    has_cluster:  (P,) bool — candidates priced with multiplier sharing;
                  the rest fall back to dense pricing (mixed populations).
    Returns dict of (P,) arrays: n_multipliers, mult_fa, adder_fa, act_fa,
    total_fa.
    """
    q = np.asarray(q, np.int64)
    P, d_in, d_out = q.shape
    w_bits = np.broadcast_to(np.asarray(w_bits, np.int64), (P,))
    in_bits = np.broadcast_to(np.asarray(in_bits, np.int64), (P,))
    prod_width = in_bits + w_bits                            # (P,)
    active = np.abs(q) > 0                                   # (P,d_in,d_out)

    n_mult = active.sum(axis=(1, 2)).astype(np.int64)
    csd_sum = (_csd_vec(q)).sum(axis=(1, 2))
    if cluster_idx is not None:
        cb = np.asarray(cluster_codebook_q, np.int64)
        sel = _used_clusters(np.asarray(cluster_idx), active, cb.shape[-1])
        sel &= np.abs(cb) > 0                                # (P,d_in,k)
        has = (np.ones(P, bool) if has_cluster is None
               else np.asarray(has_cluster, bool))
        n_mult = np.where(has, sel.sum(axis=(1, 2)), n_mult)
        csd_sum = np.where(has, (_csd_vec(cb) * sel).sum(axis=(1, 2)),
                           csd_sum)
    mult_fa = (csd_sum * prod_width).astype(np.float64) * MULT_ROUTING_FACTOR

    operands = active.sum(axis=1)                            # (P, d_out)
    adder_fa = ((np.maximum(operands - 1, 0) + 1).sum(axis=1)
                * prod_width).astype(np.float64)

    acc_w = prod_width + _ceil_log2(np.maximum(operands.max(axis=1), 2))
    act_fa = (d_out * RELU_FA_EQ * acc_w if relu
              else np.zeros(P, np.float64))

    return {"n_multipliers": n_mult, "mult_fa": mult_fa,
            "adder_fa": adder_fa, "act_fa": np.asarray(act_fa, np.float64),
            "total_fa": mult_fa + adder_fa + act_fa}


def mlp_cost_batch(q_layers: Sequence[np.ndarray], *, w_bits,
                   in_bits=8,
                   clusters: Optional[Sequence] = None) -> Dict[str, np.ndarray]:
    """Price a whole population of compiled MLPs in one vectorized call.

    q_layers:  per layer, (P, d_in, d_out) integer weights
    w_bits:    per layer, (P,) int arrays (or scalars)
    in_bits:   (P,) per-candidate input bits (or a scalar for all)
    clusters:  per layer, None or (idx (P,d_in,d_out), cb (P,d_in,k),
               has_cluster (P,) bool or None)
    Returns dict of (P,) arrays: total_fa, area_mm2, power_mw,
    n_multipliers — candidate i equals `mlp_cost` on its slices exactly.
    """
    if not isinstance(w_bits, (list, tuple)):
        w_bits = [w_bits] * len(q_layers)
    P = np.asarray(q_layers[0]).shape[0]
    total_fa = np.zeros(P, np.float64)
    n_mult = np.zeros(P, np.int64)
    per_layer = []
    for i, q in enumerate(q_layers):
        cl = clusters[i] if clusters is not None else None
        idx, cbq, has = (cl if cl is not None else (None, None, None))
        lc = layer_cost_batch(
            np.asarray(q), w_bits=w_bits[i], in_bits=in_bits,
            cluster_idx=idx, cluster_codebook_q=cbq, has_cluster=has,
            relu=(i < len(q_layers) - 1))
        per_layer.append(lc)
        total_fa += lc["total_fa"]
        n_mult += lc["n_multipliers"]
    d_out = np.asarray(q_layers[-1]).shape[-1]
    last_bits = np.broadcast_to(np.asarray(w_bits[-1], np.int64), (P,))
    in_bits = np.broadcast_to(np.asarray(in_bits, np.int64), (P,))
    argmax_fa = (d_out - 1) * ARGMAX_FA_EQ * (in_bits + last_bits + 4)
    total_fa = total_fa + argmax_fa
    return {"total_fa": total_fa, "area_mm2": total_fa * AREA_FA_MM2,
            "power_mw": total_fa * POWER_FA_MW, "n_multipliers": n_mult,
            "argmax_fa": np.asarray(argmax_fa, np.float64),
            "layers": per_layer}


def mlp_cost(q_layers: Sequence[np.ndarray], *, w_bits, in_bits: int = 8,
             clusters: Optional[Sequence[Optional[Tuple[np.ndarray, np.ndarray]]]] = None
             ) -> CircuitCost:
    """q_layers: integer weights per layer (d_in, d_out). w_bits: int or
    per-layer list. clusters[i]: None or (idx, codebook_q)."""
    if isinstance(w_bits, int):
        w_bits = [w_bits] * len(q_layers)
    costs = []
    for i, q in enumerate(q_layers):
        cl = clusters[i] if clusters is not None else None
        idx, cbq = (cl if cl is not None else (None, None))
        costs.append(layer_cost(
            np.asarray(q), w_bits=int(w_bits[i]), in_bits=in_bits,
            cluster_idx=idx, cluster_codebook_q=cbq,
            relu=(i < len(q_layers) - 1)))
    # argmax over the final layer outputs
    d_out = np.asarray(q_layers[-1]).shape[1]
    acc_w = in_bits + int(w_bits[-1]) + 4
    argmax_fa = (d_out - 1) * ARGMAX_FA_EQ * acc_w
    return CircuitCost(layers=costs, argmax_fa=argmax_fa)
