"""Pallas TPU kernels for the compute hot-spots of the paper's technique,
adapted to the TPU memory hierarchy (DESIGN.md §3):

  quant_matmul        -- int8/int4-grid weights dequantized HBM->VMEM (paper's
                         quantization: cuts the decode memory-roofline term)
  clustered_matmul    -- codebook+index weights reconstructed in VMEM (paper's
                         weight clustering: the shareable unit on TPU is an
                         HBM transfer, not a product wire)
  block_sparse_matmul -- zero (bk,bn) tiles skipped via pl.when (paper's
                         pruning: the MXU's skippable unit is a tile)
  flash_attention     -- online-softmax attention, causal + sliding window
                         (keeps scores in VMEM; the memory-roofline fix for
                         the attention-heavy cells)
  ssm_scan            -- Mamba-1 selective scan with the time loop inside the
                         kernel and the recurrent state in VMEM scratch (the
                         TPU-native analogue of the CUDA selective_scan)
  netlist_sim         -- population-batched printed-netlist simulation: dense
                         packed node tables, grid over candidates x input
                         tiles, levels as an unrolled scan (the engine behind
                         the default netlist-exact GA objective)

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper; interpret=True on CPU) and ref.py (oracle); tests sweep
shapes/dtypes and assert bit-exactness / allclose against the oracle.
"""
from jax.experimental.pallas import tpu as _pltpu

# jax renamed TPUCompilerParams -> CompilerParams; kernels import this alias
# so they build on both sides of the rename.
CompilerParams = getattr(_pltpu, "CompilerParams", None) or \
    getattr(_pltpu, "TPUCompilerParams")
