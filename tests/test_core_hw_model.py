"""Tests for the printed bespoke area/power model."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import hw_model as HW

BOUND = 2 ** 62 - 1          # documented exactness range of the vec path


def test_csd_known_values():
    # 0 -> 0 digits; powers of two -> 1; 3 = 4-1 -> 2; 7 = 8-1 -> 2
    assert HW.csd_nonzero_digits(0) == 0
    for p in (1, 2, 4, 8, 64):
        assert HW.csd_nonzero_digits(p) == 1
    assert HW.csd_nonzero_digits(3) == 2
    assert HW.csd_nonzero_digits(7) == 2
    assert HW.csd_nonzero_digits(-7) == 2
    # 0b10101 = 21 -> 3 nonzero digits
    assert HW.csd_nonzero_digits(21) == 3


def test_csd_never_exceeds_binary_ones():
    for c in range(1, 512):
        assert HW.csd_nonzero_digits(c) <= bin(c).count("1")


def test_csd_vec_matches_scalar_at_int64_boundary():
    """Deterministic spot-check of the vectorized recoding at the edges of
    its documented |c| < 2**62 range (runs with or without hypothesis)."""
    cases = np.array([0, 1, -1, 3, -3, 2 ** 61, -(2 ** 61), BOUND, -BOUND,
                      BOUND - 1, 2 ** 61 + 2 ** 59, 0x5555555555555555 >> 2,
                      -(0x2AAAAAAAAAAAAAAA)], np.int64)
    ref = [HW.csd_nonzero_digits(int(c)) for c in cases]
    np.testing.assert_array_equal(HW.csd_nonzero_digits_vec(cases), ref)
    # the tensor shape is irrelevant to the recoding
    np.testing.assert_array_equal(
        HW.csd_nonzero_digits_vec(cases.reshape(13, 1, 1)).reshape(-1), ref)


@settings(max_examples=300, deadline=None)
@given(st.lists(st.integers(min_value=-BOUND, max_value=BOUND),
                min_size=1, max_size=64))
def test_csd_vec_matches_scalar_property(xs):
    """Property: the array bit-twiddling recoding equals the scalar loop on
    arbitrary int64 tensors — negatives and the |c| < 2**62 boundary
    included (hypothesis-optional via tests/_hypothesis_compat.py)."""
    arr = np.asarray(xs, np.int64)
    ref = np.array([HW.csd_nonzero_digits(int(c)) for c in xs], np.int64)
    np.testing.assert_array_equal(HW.csd_nonzero_digits_vec(arr), ref)


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=-BOUND, max_value=BOUND))
def test_csd_digits_recoding_is_canonical(c):
    """Property: `csd_digits` reconstructs c exactly, its digit count is
    `csd_nonzero_digits(c)`, and no two non-zero digits are adjacent."""
    digits = HW.csd_digits(c)
    assert sum(s << p for p, s in digits) == c
    assert len(digits) == HW.csd_nonzero_digits(c)
    pos = sorted(p for p, _ in digits)
    assert all(b - a >= 2 for a, b in zip(pos, pos[1:]))


def test_zero_weights_cost_nothing():
    q = np.zeros((8, 4), np.int64)
    c = HW.layer_cost(q, w_bits=8, in_bits=8)
    assert c.n_multipliers == 0 and c.mult_fa == 0.0


def test_pruning_reduces_cost():
    rng = np.random.default_rng(0)
    q = rng.integers(-127, 128, (16, 8))
    dense = HW.layer_cost(q, w_bits=8, in_bits=8)
    qp = q.copy()
    qp[np.abs(qp) < 64] = 0
    pruned = HW.layer_cost(qp, w_bits=8, in_bits=8)
    assert pruned.total_fa < dense.total_fa
    assert pruned.n_multipliers < dense.n_multipliers


def test_fewer_bits_cheaper():
    rng = np.random.default_rng(1)
    w = rng.normal(size=(16, 8))
    q8 = np.round(w / np.abs(w).max() * 127).astype(np.int64)
    q3 = np.round(w / np.abs(w).max() * 3).astype(np.int64)
    c8 = HW.layer_cost(q8, w_bits=8, in_bits=8)
    c3 = HW.layer_cost(q3, w_bits=3, in_bits=8)
    assert c3.total_fa < 0.5 * c8.total_fa


def test_clustering_shares_multipliers():
    rng = np.random.default_rng(2)
    q = rng.integers(-127, 128, (8, 32))
    q[q == 0] = 1
    dense = HW.layer_cost(q, w_bits=8, in_bits=8)
    # cluster each row to 3 values
    idx = np.zeros_like(q)
    cb = np.zeros((8, 3), np.int64)
    for i in range(8):
        qs = np.quantile(q[i], [0.2, 0.5, 0.8]).astype(np.int64)
        cb[i] = np.where(qs == 0, 1, qs)
        idx[i] = np.argmin(np.abs(q[i][:, None] - cb[i][None]), axis=1)
    qc = np.take_along_axis(cb, idx, axis=1)
    clustered = HW.layer_cost(qc, w_bits=8, in_bits=8, cluster_idx=idx,
                              cluster_codebook_q=cb)
    assert clustered.n_multipliers <= 8 * 3
    assert clustered.mult_fa < dense.mult_fa
    # adder trees unchanged: sharing saves multipliers, not sums
    assert clustered.adder_fa == dense.adder_fa


def test_mlp_cost_aggregates():
    rng = np.random.default_rng(3)
    q1 = rng.integers(-127, 128, (11, 10))
    q2 = rng.integers(-127, 128, (10, 7))
    c = HW.mlp_cost([q1, q2], w_bits=8)
    assert c.area_mm2 > 0 and c.power_mw > 0
    assert c.n_multipliers == int((q1 != 0).sum() + (q2 != 0).sum())
    # printed-scale sanity: tens of cm^2 for a whitewine-sized MLP
    assert 500 < c.area_mm2 < 30000
