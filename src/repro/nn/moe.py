"""Mixture-of-Experts FFN with sort-based (MegaBlocks-style) dispatch.

Why sort-based: the classic GShard one-hot dispatch einsum costs
O(tokens^2 * k * d / E) FLOPs — quadratic in tokens and pure overhead. Here
routing builds integer slot assignments (argsort + searchsorted, negligible
FLOPs), tokens are gathered into (E, C, d) capacity buffers, experts run as
one stacked einsum (E sharded over the "model" mesh axis = expert
parallelism), and results scatter-add back weighted by router probs. HLO
FLOPs stay proportional to *active* parameters, which keeps the roofline
analysis honest.

Dropped tokens (capacity overflow) contribute zero — standard capacity-factor
semantics; the residual stream still carries them.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.nn import layers as L


def moe_init(key, cfg: ArchConfig, dtype):
    m: MoEConfig = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    E, de = m.num_experts, m.d_expert
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(de)
    p = {
        "router": {"kernel": L._trunc_normal(ks[0], (d, E), std_in,
                                             jnp.float32)},
        "experts": {
            "wi_gate": L._trunc_normal(ks[1], (E, d, de), std_in, dtype),
            "wi_up": L._trunc_normal(ks[2], (E, d, de), std_in, dtype),
            "wo": L._trunc_normal(ks[3], (E, de, d), std_out, dtype),
        },
    }
    if m.num_shared_experts:
        p["shared"] = L.mlp_init(ks[4], d, m.d_shared or m.d_expert,
                                 "swiglu", dtype)
    return p


def _route(logits, m: MoEConfig):
    """logits (S,E) fp32 -> (weights (S,k), ids (S,k), aux load-balance loss)."""
    if m.router_softmax:
        probs = jax.nn.softmax(logits, axis=-1)
    else:
        probs = jax.nn.sigmoid(logits)
    topw, topi = jax.lax.top_k(probs, m.top_k)
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E * sum_e f_e * p_e
    E = logits.shape[-1]
    me = jnp.mean(probs, axis=0)                                  # (E,)
    one_hot_top1 = jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = E * jnp.sum(me * ce)
    return topw, topi, aux


def moe_apply(p, x, cfg: ArchConfig):
    """x: (B,T,d) -> (out (B,T,d), aux_loss scalar).

    dispatch="per_sample" routes each batch row independently (vmap over B):
    the argsort/gather/scatter never crosses the batch sharding, so under
    data parallelism the dispatch is collective-free — only the (E-sharded)
    expert einsum communicates. dispatch="global" is the naive single-pool
    form (kept as the §Perf baseline; its token gather all-gathers S*k rows).
    """
    m: MoEConfig = cfg.moe
    if m.dispatch == "per_sample" and x.shape[0] > 1:
        outs, aux = jax.vmap(
            lambda xb: _moe_tokens(p, xb, cfg))(x)
        if "shared" in p:
            outs = outs + L.mlp_apply(p["shared"], x, "swiglu")
        return outs, jnp.mean(aux)
    B, T, d = x.shape
    out, aux = _moe_tokens(p, x.reshape(B * T, d), cfg, batch_shape=(B, T))
    if "shared" in p:
        out = out + L.mlp_apply(p["shared"], x.reshape(B * T, d),
                                "swiglu").reshape(B, T, d)
    return out, aux


def _moe_tokens(p, xf, cfg: ArchConfig, batch_shape=None):
    """Route a flat token block (S, d). Returns ((S,d) or batch_shape, aux)."""
    m: MoEConfig = cfg.moe
    S, d = xf.shape
    E, k = m.num_experts, m.top_k
    C = max(1, int(math.ceil(S * k / E * m.capacity_factor)))

    logits = xf.astype(jnp.float32) @ p["router"]["kernel"]
    topw, topi, aux = _route(logits, m)

    flat_e = topi.reshape(-1)                                     # (S*k,)
    flat_t = jnp.repeat(jnp.arange(S, dtype=jnp.int32), k)
    flat_w = topw.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    starts = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype))  # (E,)
    pos = jnp.arange(S * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos < C
    slot = jnp.where(keep, se.astype(jnp.int32) * C + pos, E * C)

    # gather tokens into capacity buffers (extra row swallows overflow)
    buf = jnp.zeros((E * C + 1, d), xf.dtype).at[slot].set(xf[st])
    buf = buf[:E * C].reshape(E, C, d)

    # stacked expert FFN (swiglu) — E is the expert-parallel axis
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["experts"]["wi_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, p["experts"]["wi_up"])
    y = jnp.einsum("ecf,efd->ecd", g * u, p["experts"]["wo"])
    yflat = y.reshape(E * C, d)

    contrib = yflat[jnp.minimum(slot, E * C - 1)] \
        * (sw * keep.astype(sw.dtype))[:, None].astype(yflat.dtype)
    out = jnp.zeros((S, d), xf.dtype).at[st].add(contrib.astype(xf.dtype))

    if batch_shape is not None:
        out = out.reshape(batch_shape + (d,))
    return out, aux
