"""Dense node-table packing for population-batched netlist simulation.

A compiled netlist (`repro.circuit.ir`) is a flat list of typed integer
nodes. For the population kernel every candidate is re-laid-out into dense
int32 tables over *slots* — node positions in level-major topological order
(levels ascending; ids ascending inside a level, which is deterministic and
dependency-safe because a node's operands always live in strictly earlier
levels):

    op[s]        opcode (ir.Op value; NOP = -1 marks padding slots)
    arg_a[s]     first-operand SLOT index   (0 for CONST/INPUT/ARGMAX)
    arg_b[s]     second-operand SLOT index  (ADD/SUB only, else 0)
    shift[s]     immediate shift amount     (SHL/TRUNC only, else 0)
    val[s]       hardwired payload          (CONST only, else 0; int64)
    orig_id[s]   the source node id — carried so packing is invertible
    level_ptr[l] slot range of level l is [level_ptr[l], level_ptr[l+1])

plus the slot positions of the ADC input lanes (``input_pos``, in
``net.input_ids`` order) and of the argmax comparator's operands
(``argmax_pos`` — the comparator's *actual* inputs, which approximation
passes may truncate). The ARGMAX node itself occupies a slot but is never
executed: the comparator tree is evaluated by the engine's final gather.

A :class:`PackedPopulation` stacks P candidates padded to the population
maxima (slots to ``max n_nodes``, levels to ``max n_levels``): padding
slots carry ``op = NOP`` and ``orig_id = -1``; padded ``level_ptr`` tails
repeat ``n_nodes`` so every level window degenerates to empty. ``max_width``
is the verifier's per-node width bound maximized over the population — the
engines pick int32 lanes iff it is <= 32 (`repro.verify.netlist.fits_int32`
semantics).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple, Union

import numpy as np

from repro.circuit import ir

# Padding-slot opcode sentinel. Real opcodes are ir.Op values 0..8.
NOP = -1

# Opcodes the engines execute (everything except CONST/INPUT seeding and
# the terminal ARGMAX gather).
COMPUTE_OPS = (ir.Op.SHL, ir.Op.ADD, ir.Op.SUB, ir.Op.NEG, ir.Op.RELU,
               ir.Op.TRUNC)


@dataclasses.dataclass(frozen=True)
class PackedNetlist:
    """One candidate's dense node table in level-major slot order."""
    op: np.ndarray          # (n,) int32
    arg_a: np.ndarray       # (n,) int32 slot index
    arg_b: np.ndarray       # (n,) int32 slot index
    shift: np.ndarray       # (n,) int32
    val: np.ndarray         # (n,) int64
    orig_id: np.ndarray     # (n,) int32
    level_ptr: np.ndarray   # (L+1,) int32
    input_pos: np.ndarray   # (n_in,) int32
    argmax_pos: np.ndarray  # (C,) int32
    max_width: int

    @property
    def n_nodes(self) -> int:
        return int(self.op.shape[0])

    @property
    def n_levels(self) -> int:
        return int(self.level_ptr.shape[0]) - 1


@dataclasses.dataclass(frozen=True)
class PackedPopulation:
    """P candidates' tables stacked and padded to the population maxima."""
    op: np.ndarray          # (P, N) int32, NOP on padding slots
    arg_a: np.ndarray       # (P, N) int32
    arg_b: np.ndarray       # (P, N) int32
    shift: np.ndarray       # (P, N) int32
    val: np.ndarray         # (P, N) int64
    orig_id: np.ndarray     # (P, N) int32, -1 on padding slots
    level_ptr: np.ndarray   # (P, L+1) int32
    input_pos: np.ndarray   # (P, n_in) int32
    argmax_pos: np.ndarray  # (P, C) int32
    n_nodes: np.ndarray     # (P,) int32
    n_levels: np.ndarray    # (P,) int32
    max_width: int

    @property
    def n_candidates(self) -> int:
        return int(self.op.shape[0])

    @property
    def n_slots(self) -> int:
        return int(self.op.shape[1])

    @property
    def n_inputs(self) -> int:
        return int(self.input_pos.shape[1])

    @property
    def n_classes(self) -> int:
        return int(self.argmax_pos.shape[1])


def pack_netlist(net: ir.Netlist) -> PackedNetlist:
    """Lay one netlist out as dense level-major slot tables."""
    from repro.verify.netlist import max_sim_width
    levels = net.levels()
    order: List[int] = []
    ptr = [0]
    for lev in levels:
        order.extend(sorted(lev))
        ptr.append(len(order))
    n = len(order)
    if n != len(net.nodes):
        raise ValueError(f"levels() covered {n}/{len(net.nodes)} nodes")
    pos = {nid: s for s, nid in enumerate(order)}

    op = np.zeros(n, np.int32)
    arg_a = np.zeros(n, np.int32)
    arg_b = np.zeros(n, np.int32)
    shift = np.zeros(n, np.int32)
    val = np.zeros(n, np.int64)
    orig = np.zeros(n, np.int32)
    for s, nid in enumerate(order):
        nd = net.nodes[nid]
        op[s] = int(nd.op)
        orig[s] = nid
        if nd.op == ir.Op.CONST:
            val[s] = nd.value
        elif nd.op in (ir.Op.SHL, ir.Op.TRUNC):
            arg_a[s] = pos[nd.args[0]]
            shift[s] = nd.shift
        elif nd.op in (ir.Op.ADD, ir.Op.SUB):
            arg_a[s] = pos[nd.args[0]]
            arg_b[s] = pos[nd.args[1]]
        elif nd.op in (ir.Op.NEG, ir.Op.RELU):
            arg_a[s] = pos[nd.args[0]]
        # INPUT seeded below; ARGMAX operands live in argmax_pos

    # the decision is taken over what the comparator tree actually sees
    # (approximation passes may interpose TRUNC nodes) — mirror
    # circuit.simulate.build_plan's convention exactly
    am = (net.nodes[net.argmax_id].args if net.argmax_id is not None
          else net.output_ids)
    return PackedNetlist(
        op=op, arg_a=arg_a, arg_b=arg_b, shift=shift, val=val, orig_id=orig,
        level_ptr=np.array(ptr, np.int32),
        input_pos=np.array([pos[i] for i in net.input_ids], np.int32),
        argmax_pos=np.array([pos[i] for i in am], np.int32),
        max_width=max_sim_width(net))


def pack_population(items: Sequence[Union[ir.Netlist, PackedNetlist]]
                    ) -> PackedPopulation:
    """Stack candidates (netlists or pre-packed tables) padded to the
    population maxima. All candidates must agree on input/class arity —
    one launch simulates one dataset."""
    if not items:
        raise ValueError("empty population")
    packs = [p if isinstance(p, PackedNetlist) else pack_netlist(p)
             for p in items]
    n_in = {p.input_pos.shape[0] for p in packs}
    n_cls = {p.argmax_pos.shape[0] for p in packs}
    if len(n_in) != 1 or len(n_cls) != 1:
        raise ValueError(f"mixed arities in one launch: inputs {sorted(n_in)}"
                         f", classes {sorted(n_cls)}")
    P = len(packs)
    N = max(p.n_nodes for p in packs)
    L = max(p.n_levels for p in packs)

    op = np.full((P, N), NOP, np.int32)
    arg_a = np.zeros((P, N), np.int32)
    arg_b = np.zeros((P, N), np.int32)
    shift = np.zeros((P, N), np.int32)
    val = np.zeros((P, N), np.int64)
    orig = np.full((P, N), -1, np.int32)
    ptr = np.zeros((P, L + 1), np.int32)
    for i, p in enumerate(packs):
        n = p.n_nodes
        op[i, :n] = p.op
        arg_a[i, :n] = p.arg_a
        arg_b[i, :n] = p.arg_b
        shift[i, :n] = p.shift
        val[i, :n] = p.val
        orig[i, :n] = p.orig_id
        ptr[i, :p.n_levels + 1] = p.level_ptr
        ptr[i, p.n_levels + 1:] = n       # trailing levels are empty
    return PackedPopulation(
        op=op, arg_a=arg_a, arg_b=arg_b, shift=shift, val=val, orig_id=orig,
        level_ptr=ptr,
        input_pos=np.stack([p.input_pos for p in packs]),
        argmax_pos=np.stack([p.argmax_pos for p in packs]),
        n_nodes=np.array([p.n_nodes for p in packs], np.int32),
        n_levels=np.array([p.n_levels for p in packs], np.int32),
        max_width=max(p.max_width for p in packs))


def unpack_netlist(pop: PackedPopulation, p: int
                   ) -> Dict[int, Tuple[int, Tuple[int, ...], int, int]]:
    """Invert packing for candidate ``p``:

    -> {orig_node_id: (op, arg orig-ids, shift, const value)}.

    ARGMAX rows report the comparator's operand ids (``argmax_pos`` mapped
    back through ``orig_id``) since packing stores them out of line. Used
    by the round-trip property test — a lossy packer would silently
    simulate a different circuit.
    """
    n = int(pop.n_nodes[p])
    orig = pop.orig_id[p, :n]
    out: Dict[int, Tuple[int, Tuple[int, ...], int, int]] = {}
    for s in range(n):
        o = int(pop.op[p, s])
        if o == int(ir.Op.CONST):
            args: Tuple[int, ...] = ()
        elif o == int(ir.Op.INPUT):
            args = ()
        elif o == int(ir.Op.ARGMAX):
            args = tuple(int(orig[c]) for c in pop.argmax_pos[p])
        elif o in (int(ir.Op.ADD), int(ir.Op.SUB)):
            args = (int(orig[pop.arg_a[p, s]]), int(orig[pop.arg_b[p, s]]))
        else:                              # SHL/NEG/RELU/TRUNC: unary
            args = (int(orig[pop.arg_a[p, s]]),)
        sh = (int(pop.shift[p, s])
              if o in (int(ir.Op.SHL), int(ir.Op.TRUNC)) else 0)
        v = int(pop.val[p, s]) if o == int(ir.Op.CONST) else 0
        out[int(orig[s])] = (o, args, sh, v)
    return out
