"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP. [arXiv:2402.16819]"""
from repro.configs.base import ArchConfig, LayerSpec, Segment

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    d_model=18432,
    vocab_size=256000,
    segments=(Segment((LayerSpec("attn", "dense"),), 96),),
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,                      # 18432 / 96
    d_ff=73728,
    mlp_type="relu2",                  # squared ReLU, no gating
    rope_theta=10000.0,
    source="arXiv:2402.16819; unverified",
)
