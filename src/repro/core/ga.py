"""Hardware-aware NSGA-II genetic algorithm (paper Fig. 2).

Genome: one (bits, sparsity, clusters) gene per compressible layer.
Objectives (all minimized): (1 - accuracy, hardware cost[, ...]). The
evaluation callback is pluggable — printed area (mm^2) for the paper's
MLPs, roofline seconds (`core.tpu_cost`) for the beyond-paper LM
integration; "hardware-aware" means the GA sees the real deployment cost,
not a proxy. Evaluators may return more than two objectives (NSGA-II's
sorting/crowding are dimension-agnostic): the netlist-exact evaluator
(`batch_eval.make_batch_evaluator(netlist=True, include_delay=True)`)
adds the compiled circuit's critical-path delay as a third objective,
which the analytic cost model cannot express.

With ``csd_drop_choices`` / ``lsb_choices`` widened past ``(0,)`` the
genome also carries circuit-approximation genes (`repro.approx`): the GA
then trades bounded arithmetic error inside the bespoke netlist for area,
on top of the paper's quant/prune/cluster axes. Approximated candidates
are priced structurally and scored on the simulated approximate circuit
(`batch_eval` switches per candidate automatically).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.compression_spec import LayerMin, ModelMin
from repro.core.pareto import (crowding_distance, non_dominated_sort,
                               pareto_front)
from repro.obs import metrics as MT
from repro.obs import trace as TR

BITS_CHOICES = (2, 3, 4, 5, 6, 7, 8)
SPARSITY_CHOICES = (0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7)
CLUSTER_CHOICES = (None, 2, 3, 4, 6, 8, 12, 16)
# circuit-approximation genes (repro.approx). Off by default: the single
# (0,) choice draws nothing from the RNG, so exact searches reproduce
# their historical trajectories bit-for-bit.
CSD_DROP_CHOICES = (0, 1, 2, 3)
LSB_CHOICES = (0, 1, 2, 3, 4, 6)
ARGMAX_LSB_CHOICES = (0, 2, 4, 6, 8)


@dataclasses.dataclass
class GAConfig:
    population: int = 16
    generations: int = 8
    crossover_prob: float = 0.9
    mutation_prob: float = 0.25
    seed: int = 0
    input_bits: int = 8                  # propagated into random genomes
    bits_choices: Sequence = BITS_CHOICES
    sparsity_choices: Sequence = SPARSITY_CHOICES
    cluster_choices: Sequence = CLUSTER_CHOICES
    # set to CSD_DROP_CHOICES / LSB_CHOICES / ARGMAX_LSB_CHOICES (or your
    # own) to let the GA search bespoke-circuit approximation alongside
    # quant/prune/cluster
    csd_drop_choices: Sequence = (0,)
    lsb_choices: Sequence = (0,)
    argmax_lsb_choices: Sequence = (0,)   # model-level gene (one comparator)

    @property
    def approx_enabled(self) -> bool:
        return tuple(self.csd_drop_choices) != (0,) \
            or tuple(self.lsb_choices) != (0,) \
            or tuple(self.argmax_lsb_choices) != (0,)


@dataclasses.dataclass
class GAResult:
    population: List[ModelMin]
    objectives: np.ndarray               # (N, K>=2) minimized
    history: List[Dict]                  # per-generation stats
    evaluations: Dict[str, Tuple[float, ...]]  # spec json -> objectives
    # specs whose evaluation failed (retried once, then given worst-case
    # fitness) — `batch_eval.QuarantineRecord`s with the stage/error that
    # sank them; empty on clean runs
    quarantined: List = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class GAState:
    """Resumable NSGA-II state between generations.

    ``rng_state`` is the exact ``random.Random.getstate()`` tuple, so a
    search advanced one :func:`ga_generation` at a time consumes the same
    RNG stream as the monolithic :func:`run_nsga2` loop — checkpointed and
    resumed searches are bit-identical to uninterrupted ones.
    """
    population: List[ModelMin]
    rng_state: Tuple
    generation: int = 0
    history: List[Dict] = dataclasses.field(default_factory=list)


def _random_gene(rng, cfg: GAConfig) -> LayerMin:
    g = LayerMin(bits=rng.choice(cfg.bits_choices),
                 sparsity=rng.choice(cfg.sparsity_choices),
                 clusters=rng.choice(cfg.cluster_choices))
    if cfg.approx_enabled:               # extra draws only when searching
        g = dataclasses.replace(g,
                                csd_drop=rng.choice(cfg.csd_drop_choices),
                                lsb=rng.choice(cfg.lsb_choices))
    return g


def _mutate(spec: ModelMin, rng, cfg: GAConfig) -> ModelMin:
    fields = ["bits", "sparsity", "clusters"]
    if cfg.approx_enabled:
        fields += ["csd_drop", "lsb"]
    genes = list(spec.layers)
    for i, g in enumerate(genes):
        if rng.random() < cfg.mutation_prob:
            field = rng.choice(fields)
            if field == "bits":
                genes[i] = dataclasses.replace(g, bits=rng.choice(cfg.bits_choices))
            elif field == "sparsity":
                genes[i] = dataclasses.replace(
                    g, sparsity=rng.choice(cfg.sparsity_choices))
            elif field == "clusters":
                genes[i] = dataclasses.replace(
                    g, clusters=rng.choice(cfg.cluster_choices))
            elif field == "csd_drop":
                genes[i] = dataclasses.replace(
                    g, csd_drop=rng.choice(cfg.csd_drop_choices))
            else:
                genes[i] = dataclasses.replace(
                    g, lsb=rng.choice(cfg.lsb_choices))
    argmax_lsb = spec.argmax_lsb
    if cfg.approx_enabled and rng.random() < cfg.mutation_prob:
        argmax_lsb = rng.choice(cfg.argmax_lsb_choices)
    return ModelMin(tuple(genes), spec.input_bits, argmax_lsb)


def _crossover(a: ModelMin, b: ModelMin, rng) -> ModelMin:
    genes = tuple(ga if rng.random() < 0.5 else gb
                  for ga, gb in zip(a.layers, b.layers))
    # the model-level gene recombines 50/50 like the per-layer ones; the
    # draw happens only when the parents disagree, so exact searches
    # (argmax_lsb always 0) keep their historical RNG stream
    am = a.argmax_lsb
    if a.argmax_lsb != b.argmax_lsb and rng.random() < 0.5:
        am = b.argmax_lsb
    return ModelMin(genes, a.input_bits, am)


def _tournament(idx_ranked: List[int], rng) -> int:
    i, j = rng.sample(range(len(idx_ranked)), 2)
    return idx_ranked[min(i, j)]


def _ranked_with_fronts(objs: np.ndarray):
    fronts = non_dominated_sort(objs)
    ranked: List[int] = []
    for f in fronts:
        if len(f) == 0:
            continue
        cd = crowding_distance(objs[f])
        ranked.extend(int(i) for i in f[np.argsort(-cd)])
    return ranked, fronts


def rank_population(objs: np.ndarray) -> List[int]:
    """Population indices best-first: non-domination rank, crowding-distance
    tiebreak — the ordering NSGA-II's tournament selection sees. Exposed for
    the island fleet (elite selection for migration uses the same ranking)."""
    return _ranked_with_fronts(objs)[0]


def init_ga_state(n_layers: int, cfg: GAConfig,
                  seed_specs: Optional[List[ModelMin]] = None) -> GAState:
    """Generation-0 state: seed specs + random genomes, RNG stream exported.
    Byte-identical population to `run_nsga2`'s initialisation."""
    rng = random.Random(cfg.seed)
    # propagate input_bits into random genomes: seed specs win, else config
    input_bits = seed_specs[0].input_bits if seed_specs else cfg.input_bits
    pop: List[ModelMin] = list(seed_specs or [])
    while len(pop) < cfg.population:
        genes = tuple(_random_gene(rng, cfg) for _ in range(n_layers))
        # the model-level gene is sampled at init like the per-layer ones
        # (drawn only when approximation is searched: exact configs keep
        # their historical RNG stream)
        am = (rng.choice(cfg.argmax_lsb_choices) if cfg.approx_enabled
              else 0)
        pop.append(ModelMin(genes, input_bits, am))
    return GAState(pop, rng.getstate())


def ga_generation(state: GAState, cfg: GAConfig,
                  fit_all: Callable[[List[ModelMin]], np.ndarray], *,
                  n_children: Optional[int] = None) -> GAState:
    """One NSGA-II generation as a PURE function: rank, breed, mu+lambda
    select. Returns a new state; the input state is never mutated, so a
    caller that catches an exception from `fit_all` (worker death, injected
    fault) rolls back for free by simply keeping the old state.

    ``n_children`` overrides the offspring count for this generation only
    (default ``cfg.population`` — the `run_nsga2` behaviour); the island
    fleet uses it to deal an ejected island's offspring budget over the
    survivors. Selection pressure is unchanged: the environmental selection
    still keeps the best ``cfg.population`` of parents+children.
    """
    rng = random.Random()
    rng.setstate(state.rng_state)
    pop = list(state.population)
    if n_children is None:
        n_children = cfg.population
    objs = fit_all(pop)
    ranked, fronts = _ranked_with_fronts(objs)
    entry = {
        "generation": state.generation,
        "best_acc": float(1.0 - objs[:, 0].min()),
        "min_cost": float(objs[:, 1].min()),
        "front_size": int(len(fronts[0])),
    }
    if objs.shape[1] > 2:          # netlist-exact delay objective
        entry["min_delay"] = float(objs[:, 2].min())
    # offspring
    children: List[ModelMin] = []
    while len(children) < n_children:
        pa, pb = pop[_tournament(ranked, rng)], pop[_tournament(ranked, rng)]
        child = _crossover(pa, pb, rng) if rng.random() < cfg.crossover_prob else pa
        children.append(_mutate(child, rng, cfg))
    # mu + lambda environmental selection
    union = pop + children
    uobjs = fit_all(union)
    ufronts = non_dominated_sort(uobjs)
    new_pop: List[ModelMin] = []
    for f in ufronts:
        if len(new_pop) + len(f) <= cfg.population:
            new_pop.extend(union[int(i)] for i in f)
        else:
            cd = crowding_distance(uobjs[f])
            order = f[np.argsort(-cd)]
            for i in order:
                if len(new_pop) >= cfg.population:
                    break
                new_pop.append(union[int(i)])
            break
    return GAState(new_pop, rng.getstate(), state.generation + 1,
                   [*state.history, entry])


def run_nsga2(n_layers: int,
              evaluate: Optional[Callable[[ModelMin], Tuple[float, float]]],
              cfg: Optional[GAConfig] = None,
              seed_specs: Optional[List[ModelMin]] = None, *,
              batch_evaluate: Optional[
                  Callable[[List[ModelMin]], List[Tuple[float, float]]]]
              = None,
              on_generation: Optional[Callable[[GAState], None]] = None,
              quarantine: Optional[List] = None) -> GAResult:
    """evaluate(spec) -> (obj1, obj2[, ...]), all minimized (every spec
    must return the same arity). Deterministic for a fixed GAConfig.seed.
    Memoizes repeated specs.

    When `batch_evaluate` is given (e.g. `batch_eval.make_batch_evaluator`),
    every generation's uncached specs are fitted in ONE call — the batched
    engine runs the whole population's QAT finetune in a single jit instead
    of N sequential traces.

    ``on_generation`` is called with the new :class:`GAState` after every
    generation — the checkpointing hook (`repro.search.runtime` snapshots
    state there; any exception aborts the search with state intact).
    ``quarantine``: pass the same list given to
    `batch_eval.make_batch_evaluator(quarantine=...)` and the records of
    specs that failed evaluation surface on ``GAResult.quarantined``.
    """
    if evaluate is None and batch_evaluate is None:
        raise ValueError("need evaluate or batch_evaluate")
    if cfg is None:
        cfg = GAConfig()
    cache: Dict[str, Tuple[float, float]] = {}

    def fit_all(specs: List[ModelMin]) -> np.ndarray:
        todo, seen = [], set()
        for s in specs:
            k = s.to_json()
            if k not in cache and k not in seen:
                todo.append(s)
                seen.add(k)
        if todo:
            if batch_evaluate is not None:
                outs = batch_evaluate(todo)
            else:
                outs = [evaluate(s) for s in todo]
            for s, o in zip(todo, outs):
                cache[s.to_json()] = tuple(map(float, o))
        return np.array([cache[s.to_json()] for s in specs])

    state = init_ga_state(n_layers, cfg, seed_specs)
    for _ in range(cfg.generations):
        with TR.span("ga.generation", generation=state.generation):
            state = ga_generation(state, cfg, fit_all)
        MT.counter("ga.generations").inc()
        if TR.active() and state.history:
            # front stats + first-front objectives for the report's
            # Pareto-progress curve; ranks come from the memo, never the
            # RNG, so tracing cannot perturb the trajectory
            objs = fit_all(state.population)
            first = pareto_front(objs)
            TR.event("ga.front", generation=state.generation,
                     best_acc=state.history[-1].get("best_acc"),
                     min_cost=state.history[-1].get("min_cost"),
                     front_size=len(first),
                     front=[[round(float(v), 6) for v in objs[int(i)]]
                            for i in first])
        if on_generation is not None:
            on_generation(state)

    objs = fit_all(state.population)
    return GAResult(state.population, objs, state.history, cache,
                    quarantined=list(quarantine) if quarantine else [])
