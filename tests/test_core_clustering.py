"""Unit + property tests for weight clustering (paper §II-C)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import clustering as C


def test_kmeans_exact_when_k_matches():
    x = jnp.asarray([-1.0, -1.0, 0.5, 0.5, 2.0, 2.0])
    cent, a = C._kmeans_1d(x, 3)
    recon = np.asarray(cent)[np.asarray(a)]
    np.testing.assert_allclose(recon, np.asarray(x), atol=1e-5)


def test_per_input_row_sharing():
    w = jax.random.normal(jax.random.PRNGKey(0), (6, 20))
    cb, idx = C.cluster_per_input(w, 4)
    assert cb.shape == (6, 4) and idx.shape == (6, 20)
    rec = C.reconstruct_per_input(cb, idx)
    # each row has at most 4 distinct values => at most 4 multipliers/input
    for row in np.asarray(rec):
        assert len(np.unique(row)) <= 4


def test_multipliers_needed_counts_distinct_nonzero():
    cb = jnp.asarray([[0.0, 1.0, 2.0], [3.0, 3.5, 0.0]])
    idx = jnp.asarray([[0, 1, 1, 2], [0, 0, 1, 2]])
    # row0 uses clusters {0,1,2}, cluster0 is zero -> 2; row1 uses {0,1,2},
    # cluster2 is zero -> 2
    assert C.multipliers_needed(idx, cb) == 4


@settings(max_examples=20, deadline=None)
@given(k=st.integers(2, 12), seed=st.integers(0, 2 ** 16))
def test_property_error_decreases_with_k(k, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (8, 24))
    e_small = C.clustering_error(w, k)
    e_big = C.clustering_error(w, min(k * 2, 24))
    assert e_big <= e_small + 1e-4


def test_cluster_ste_gradient_identity():
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    g = jax.grad(lambda w: jnp.sum(C.cluster_ste(w, 3) * 2.0))(w)
    np.testing.assert_allclose(np.asarray(g), 2.0 * np.ones((4, 16)),
                               atol=1e-6)


def test_layer_codebook_reconstruction_error_small_for_large_k():
    w = jax.random.normal(jax.random.PRNGKey(2), (16, 16))
    assert C.clustering_error(w, 16, per_input=False) < \
        C.clustering_error(w, 2, per_input=False)
