"""The paper's primary contribution: hardware-aware automated neural
minimization — quantization + pruning + weight clustering, priced by the
target hardware's real cost model and searched jointly with NSGA-II."""
from repro.core import (clustering, compression_spec, ga, hw_model, minimize,
                        pareto, pruning, quantization, tpu_cost)  # noqa: F401
