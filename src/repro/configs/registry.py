"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchConfig
from repro.configs.nemotron_4_340b import CONFIG as _nemotron
from repro.configs.qwen3_0_6b import CONFIG as _qwen3
from repro.configs.gemma_7b import CONFIG as _gemma7b
from repro.configs.gemma2_2b import CONFIG as _gemma2
from repro.configs.recurrentgemma_9b import CONFIG as _rgemma
from repro.configs.whisper_base import CONFIG as _whisper
from repro.configs.falcon_mamba_7b import CONFIG as _mamba
from repro.configs.llama32_vision_11b import CONFIG as _llamav
from repro.configs.deepseek_v2_236b import CONFIG as _dsv2
from repro.configs.phi35_moe_42b import CONFIG as _phi

ARCHS: Dict[str, ArchConfig] = {
    c.name: c for c in (
        _nemotron, _qwen3, _gemma7b, _gemma2, _rgemma,
        _whisper, _mamba, _llamav, _dsv2, _phi,
    )
}

ARCH_IDS = tuple(ARCHS)


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
