"""Seeded synthetic stand-ins for the paper's four UCI datasets.

The container is offline, so the real UCI files are unavailable (DESIGN.md
§4). Each generator reproduces the dataset *schema* (feature count, class
count, sample count, class imbalance) as a class-conditional Gaussian mixture
whose difficulty is tuned so the un-minimized baseline MLP accuracy lands
near the published range for that dataset. All draws are seeded — every run
of the benchmark suite sees identical data.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.configs.printed_mlp import PRINTED_MLPS, PrintedMLPConfig


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_samples: int
    n_features: int
    n_classes: int
    class_sep: float          # mixture separation (difficulty knob)
    noise: float
    imbalance: float          # geometric class-frequency decay


SPECS = {
    # whitewine: 4898 samples, 11 features, 7 quality levels, hard/overlapping
    "whitewine": DatasetSpec("whitewine", 4898, 11, 7, 1.05, 0.85, 0.55),
    # redwine: 1599 samples, 11 features, 6 levels
    "redwine": DatasetSpec("redwine", 1599, 11, 6, 1.10, 0.85, 0.60),
    # pendigits: 10992 samples, 16 features, 10 digits, fairly separable
    "pendigits": DatasetSpec("pendigits", 10992, 16, 10, 2.6, 0.55, 1.0),
    # seeds: 210 samples, 7 features, 3 varieties, separable
    "seeds": DatasetSpec("seeds", 210, 7, 3, 2.9, 0.50, 1.0),
}


def make_dataset(name: str, *, seed: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """-> (x_train, y_train, x_test, y_test); features min-max scaled to
    [0, 1] (printed ADC front-ends deliver unsigned fixed-point inputs)."""
    spec = SPECS[name]
    # zlib.crc32, NOT hash(): str hash is randomized per process and would
    # make "seeded" datasets process-dependent
    import zlib
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 65536)
    freqs = spec.imbalance ** np.arange(spec.n_classes)
    freqs = freqs / freqs.sum()
    counts = np.maximum((freqs * spec.n_samples).astype(int), 8)

    # class means on a low-dim manifold embedded in feature space
    basis = rng.normal(size=(spec.n_classes, spec.n_features))
    means = basis * spec.class_sep
    # shared covariance structure with per-class jitter
    mix = rng.normal(size=(spec.n_features, spec.n_features)) * 0.3
    xs, ys = [], []
    for c, n in enumerate(counts):
        z = rng.normal(size=(n, spec.n_features))
        x = means[c] + z @ (np.eye(spec.n_features) + mix) * spec.noise
        # mild nonlinearity so a linear model can't saturate the task
        x = x + 0.15 * np.sin(2.0 * x[:, ::-1])
        xs.append(x)
        ys.append(np.full(n, c, np.int32))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys)
    lo, hi = x.min(axis=0), x.max(axis=0)
    x = (x - lo) / np.maximum(hi - lo, 1e-9)

    idx = rng.permutation(len(x))
    x, y = x[idx], y[idx]
    n_test = max(int(0.25 * len(x)), 16)
    return x[n_test:], y[n_test:], x[:n_test], y[:n_test]


def dataset_for(cfg: PrintedMLPConfig, *, seed: int = 0):
    assert cfg.name in SPECS
    return make_dataset(cfg.name, seed=seed)
