"""Analytic bespoke printed-circuit area/power model (simulated EGT flow).

The paper prices designs with Synopsys DC + PrimeTime + the EGT
(Electrolyte-Gated Transistor) library. Those tools are unavailable here, so
this module implements the published *structure* of bespoke-MLP cost
analytically (Mubarik MICRO'20; Armeniakos DATE'22):

* a bespoke constant-coefficient multiplier is a shift-add network whose size
  is (#non-zero CSD digits of the coefficient - 1) adders at (input_bits +
  weight_bits) width — a zero coefficient is free (no multiplier printed),
  a power-of-two coefficient is a wire shift;
* each neuron sums its non-zero products through an adder tree: (operands-1)
  adders at accumulator width; pruning removes operands, shrinking the tree;
* per-input weight clustering shares the product x_i*c across fan-out: the
  row's multiplier count collapses to its #distinct non-zero clusters
  (adder trees are unchanged — sharing saves multipliers, not sums);
* ReLU = comparator+mux, argmax = comparator tree.

Unit calibration: EGT full-adder equivalents. AREA_FA/POWER_FA are set so the
un-minimized 8-bit bespoke MLPs land in the tens-of-cm^2 / ~100 mW range
reported by MICRO'20. Absolute numbers are approximate (documented DESIGN.md
§4); the paper's *relative* claims (5x/2.8x/3.5x/8x) are what EXPERIMENTS.md
validates.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# EGT-scale constants, calibrated so (a) un-minimized 8-bit bespoke MLPs land
# at the tens-of-cm^2 / tens-of-mW magnitudes of MICRO'20 and (b) the
# multiplier/adder area split matches bespoke synthesis (multipliers ~3/4 of
# neuron area -- Armeniakos DATE'22 Fig.3): see EXPERIMENTS.md §Calibration.
AREA_FA_MM2 = 0.60          # printed 1-bit full adder, mm^2
POWER_FA_MW = 0.004         # mW per full-adder equivalent (EGT, ~few Hz duty)
RELU_FA_EQ = 2.0            # comparator+mux per output bit, FA equivalents
ARGMAX_FA_EQ = 1.2          # comparator per bit, FA equivalents
MULT_ROUTING_FACTOR = 2.0   # partial-product generation + shift routing
# overhead per CSD-digit adder: bespoke multipliers dominate printed neuron
# area (~75-85%, Armeniakos DATE'22) -- this factor sets that split


def csd_nonzero_digits(c: int) -> int:
    """Number of non-zero digits in the canonical signed-digit form of |c|.
    This is the count of shift-add/sub terms a bespoke constant multiplier
    needs (Avizienis recoding)."""
    c = abs(int(c))
    count = 0
    while c:
        if c & 1:
            count += 1
            # CSD: runs of 1s become +/- pair -> round to nearest multiple of 4
            c = c + 1 if (c & 3) == 3 else c - 1
        c >>= 1
    return count


def _csd_vec(q: np.ndarray) -> np.ndarray:
    return np.vectorize(csd_nonzero_digits, otypes=[np.int64])(q)


@dataclasses.dataclass
class LayerCost:
    n_multipliers: int
    mult_fa: float
    adder_fa: float
    act_fa: float

    @property
    def total_fa(self) -> float:
        return self.mult_fa + self.adder_fa + self.act_fa


@dataclasses.dataclass
class CircuitCost:
    layers: List[LayerCost]
    argmax_fa: float

    @property
    def total_fa(self) -> float:
        return sum(l.total_fa for l in self.layers) + self.argmax_fa

    @property
    def area_mm2(self) -> float:
        return self.total_fa * AREA_FA_MM2

    @property
    def power_mw(self) -> float:
        return self.total_fa * POWER_FA_MW

    @property
    def n_multipliers(self) -> int:
        return sum(l.n_multipliers for l in self.layers)


def layer_cost(q: np.ndarray, *, w_bits: int, in_bits: int,
               cluster_idx: Optional[np.ndarray] = None,
               cluster_codebook_q: Optional[np.ndarray] = None,
               relu: bool = True) -> LayerCost:
    """Cost of one bespoke dense layer.

    q: integer weight matrix (d_in, d_out) on the w_bits grid (0 = pruned).
    cluster_idx/codebook_q: per-input clustering (idx (d_in,d_out),
    integer codebooks (d_in, k)) — multipliers are shared within a row.
    """
    q = np.asarray(q, np.int64)
    d_in, d_out = q.shape
    prod_width = in_bits + w_bits

    # ---- multipliers -------------------------------------------------------
    # each non-zero CSD digit costs one shifted add/sub at product width
    # (the first partial product's routing/shift network included -- a
    # power-of-two coefficient is wiring, not free)
    if cluster_idx is not None:
        mult_fa = 0.0
        n_mult = 0
        cb = np.asarray(cluster_codebook_q, np.int64)
        for i in range(d_in):
            used = np.unique(cluster_idx[i][np.abs(q[i]) > 0])
            coeffs = cb[i, used]
            coeffs = coeffs[np.abs(coeffs) > 0]
            n_mult += len(coeffs)
            nnz = _csd_vec(coeffs)
            mult_fa += float(np.sum(nnz) * prod_width) * MULT_ROUTING_FACTOR
    else:
        nz = q[np.abs(q) > 0]
        n_mult = int(nz.size)
        nnz = _csd_vec(nz)
        mult_fa = float(np.sum(nnz) * prod_width) * MULT_ROUTING_FACTOR

    # ---- adder trees (per output neuron; sharing does not shrink sums).
    # Tree adders are dominated by the narrow lower levels: width ~ product
    # width (the few wide top-level adders are amortized).
    operands = (np.abs(q) > 0).sum(axis=0)                 # (d_out,)
    adder_fa = 0.0
    for m in operands:
        adder_fa += (max(m - 1, 0) + 1) * prod_width        # tree + bias add

    # ---- activation ---------------------------------------------------------
    acc_w = prod_width + math.ceil(math.log2(max(int(operands.max(initial=1)), 2)))
    act_fa = d_out * RELU_FA_EQ * acc_w if relu else 0.0

    return LayerCost(n_multipliers=n_mult, mult_fa=mult_fa,
                     adder_fa=adder_fa, act_fa=act_fa)


def mlp_cost(q_layers: Sequence[np.ndarray], *, w_bits, in_bits: int = 8,
             clusters: Optional[Sequence[Optional[Tuple[np.ndarray, np.ndarray]]]] = None
             ) -> CircuitCost:
    """q_layers: integer weights per layer (d_in, d_out). w_bits: int or
    per-layer list. clusters[i]: None or (idx, codebook_q)."""
    if isinstance(w_bits, int):
        w_bits = [w_bits] * len(q_layers)
    costs = []
    for i, q in enumerate(q_layers):
        cl = clusters[i] if clusters is not None else None
        idx, cbq = (cl if cl is not None else (None, None))
        costs.append(layer_cost(
            np.asarray(q), w_bits=int(w_bits[i]), in_bits=in_bits,
            cluster_idx=idx, cluster_codebook_q=cbq,
            relu=(i < len(q_layers) - 1)))
    # argmax over the final layer outputs
    d_out = np.asarray(q_layers[-1]).shape[1]
    acc_w = in_bits + int(w_bits[-1]) + 4
    argmax_fa = (d_out - 1) * ARGMAX_FA_EQ * acc_w
    return CircuitCost(layers=costs, argmax_fa=argmax_fa)
