"""Island-model NSGA-II fleet with straggler ejection and kill rollback.

N islands each advance an independent NSGA-II population (`core.ga`'s
stepped API — per-island `random.Random` streams seeded `cfg.seed + i`),
sharing one evaluation memo so no spec is ever fitted twice fleet-wide;
plug `batch_eval.make_batch_evaluator(cache=EvalCache(...))` in as the
evaluator and the memo extends across processes through the flock-merged
on-disk cache.

Fault model (all per *round* — one round = one generation on every
participating island):

* **Stragglers**: before each round every island reports an arrival time
  (by default its previous round's measured duration; the fault harness
  injects synthetic ones). `dist.fault_tolerance.deadline_barrier` ejects
  islands past ``deadline_s`` for the round — their state is simply not
  advanced — and `redistribute_batch` deals their offspring budget over
  the participants, so fleet-wide selection throughput is preserved
  instead of the whole fleet stalling behind one slow worker.
* **Kills**: an evaluation transport that raises :class:`IslandKilled`
  mid-generation (worker death) marks the island permanently dead. Because
  `ga_generation` is a pure function, rollback is free — the island keeps
  its last committed state, and every evaluation it published before dying
  stays in the shared memo (zero completed evaluations lost).
* **Migration**: every ``migration_every`` rounds each live island's top
  ``migrants`` (non-domination rank, crowding tiebreak) replace the worst
  members of its ring neighbour. Deterministic — no RNG draws — so the
  islands' genetic streams are untouched by migration topology.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import ga as GA
from repro.core.compression_spec import ModelMin
from repro.core.pareto import pareto_front
from repro.dist import fault_tolerance as FT
from repro.obs import metrics as MT
from repro.obs import trace as TR
from repro.obs.ring import RingLog


class IslandKilled(RuntimeError):
    """Raised from inside an island's generation (by the fault harness, or
    by a real worker transport) to signal the worker died mid-generation.
    The fleet rolls the island back to its last committed state and marks
    it dead; the survivors keep searching."""


@dataclasses.dataclass
class IslandConfig:
    n_islands: int = 4
    migration_every: int = 2          # rounds between migrations; 0 = never
    migrants: int = 2                 # elites copied to the ring neighbour
    deadline_s: float = float("inf")  # per-round straggler deadline
    redistribute_offspring: bool = True
    # in-memory caps for the fleet event / quarantine logs: only the newest
    # N stay resident (the full streams spill to the obs trace when
    # REPRO_TRACE is on) — a week-long run can't grow the process without
    # bound. `RingLog.total`/`.dropped` keep the true counts.
    event_buffer: int = 1024
    quarantine_buffer: int = 1024


@dataclasses.dataclass
class Island:
    index: int
    cfg: GA.GAConfig                  # per-island (seed = fleet seed + index)
    state: GA.GAState
    alive: bool = True                # False once killed — permanent
    ejections: int = 0                # rounds skipped as a straggler
    last_duration_s: float = 0.0      # measured; default arrival time


class IslandFleet:
    """The island fleet. Construct, then call :meth:`run_round` until
    satisfied (`search.runtime.SearchRuntime` adds checkpoint/resume and
    the result assembly on top)."""

    def __init__(self, n_layers: int, ga_cfg: GA.GAConfig,
                 icfg: Optional[IslandConfig] = None, *,
                 evaluate=None, batch_evaluate=None,
                 seed_specs: Optional[List[ModelMin]] = None,
                 timer: Optional[Callable[[int, int], float]] = None,
                 kill_hook: Optional[Callable[[int, int], None]] = None,
                 quarantine: Optional[List] = None):
        if evaluate is None and batch_evaluate is None:
            raise ValueError("need evaluate or batch_evaluate")
        self.icfg = icfg or IslandConfig()
        self.evaluate = evaluate
        self.batch_evaluate = batch_evaluate
        self.timer = timer or self._default_timer
        self.kill_hook = kill_hook
        # seed specs go to island 0 only: duplicating them fleet-wide would
        # start every island in the same basin
        self.islands = [
            Island(i, cfg_i := dataclasses.replace(ga_cfg, seed=ga_cfg.seed + i),
                   GA.init_ga_state(n_layers, cfg_i,
                                    seed_specs if i == 0 else None))
            for i in range(self.icfg.n_islands)]
        self.evaluations: Dict[str, Tuple[float, ...]] = {}
        self.round = 0
        # bounded in memory; every append also lands in the obs trace (the
        # JSONL is the complete stream, the ring is the working set)
        self.events: RingLog = RingLog(
            self.icfg.event_buffer,
            spill=lambda e: TR.event(
                "fleet." + (e.get("event", "event")
                            if isinstance(e, dict) else "event"),
                **(e if isinstance(e, dict) else {"item": e})))
        # shared with the evaluator (`make_batch_evaluator(quarantine=...)`)
        # so failing specs surface on the final SearchResult; callers may
        # pass their own (possibly unbounded) list and keep old behaviour
        self.quarantine = (quarantine if quarantine is not None
                           else RingLog(self.icfg.quarantine_buffer))

    # -- evaluation ---------------------------------------------------------

    def _fit_specs(self, specs: List[ModelMin]) -> np.ndarray:
        todo, seen = [], set()
        for s in specs:
            k = s.to_json()
            if k not in self.evaluations and k not in seen:
                todo.append(s)
                seen.add(k)
        MT.counter("fleet.specs_requested").inc(len(specs))
        MT.counter("fleet.specs_memoized").inc(len(specs) - len(todo))
        MT.counter("fleet.specs_fitted").inc(len(todo))
        TR.event("fleet.fit", round=self.round, requested=len(specs),
                 memoized=len(specs) - len(todo), fitted=len(todo))
        if todo:
            outs = (self.batch_evaluate(todo) if self.batch_evaluate
                    else [self.evaluate(s) for s in todo])
            for s, o in zip(todo, outs):
                self.evaluations[s.to_json()] = tuple(map(float, o))
        return np.array([self.evaluations[s.to_json()] for s in specs])

    def _island_fit(self, isl: Island):
        def fit(specs):
            objs = self._fit_specs(specs)
            # the kill hook fires AFTER the results are committed to the
            # shared memo — modelling a worker that published its
            # evaluations and died before finishing selection
            if self.kill_hook is not None:
                self.kill_hook(isl.index, self.round)
            return objs
        return fit

    def _default_timer(self, island_index: int, round_idx: int) -> float:
        return self.islands[island_index].last_duration_s

    # -- rounds -------------------------------------------------------------

    def run_round(self) -> None:
        r = self.round
        if not any(isl.alive for isl in self.islands):
            raise RuntimeError("island fleet: every island is dead")
        with TR.span("fleet.round", round=r):
            self._run_round_inner(r)
        MT.counter("fleet.rounds").inc()
        if (self.icfg.migration_every
                and self.round % self.icfg.migration_every == 0):
            self._migrate()

    def _run_round_inner(self, r: int) -> None:
        times = [self.timer(isl.index, r) if isl.alive else float("inf")
                 for isl in self.islands]
        made = FT.deadline_barrier(times, self.icfg.deadline_s)
        participate = [m and isl.alive
                       for m, isl in zip(made, self.islands)]
        if not any(participate):
            # every live island straggled: waive the deadline for the round
            # rather than deadlock the fleet behind its own barrier
            participate = [isl.alive for isl in self.islands]
            self.events.append({"round": r, "event": "all_straggle_waived"})
        # deal the non-participants' per-round offspring budget over the
        # participants: fleet-wide selection throughput survives ejections
        extra = sum(isl.cfg.population
                    for isl, p in zip(self.islands, participate) if not p)
        if extra and self.icfg.redistribute_offspring:
            deal = FT.redistribute_batch(extra, participate)
        else:
            deal = {i: 0 for i in range(len(self.islands))}
        for isl, p in zip(self.islands, participate):
            if not p:
                if isl.alive:
                    isl.ejections += 1
                    MT.counter("island.ejections").inc()
                    self.events.append(
                        {"round": r, "island": isl.index,
                         "event": "straggler_ejected",
                         "arrival_s": float(times[isl.index])})
                continue
            t0 = time.monotonic()
            try:
                with TR.span("island.generation", island=isl.index,
                             round=r, generation=isl.state.generation):
                    isl.state = GA.ga_generation(
                        isl.state, isl.cfg, self._island_fit(isl),
                        n_children=isl.cfg.population + deal[isl.index])
                MT.counter("island.generations").inc()
                self._trace_front(isl, r)
            except IslandKilled as e:
                # pure-function rollback: state was never touched; its
                # published evaluations stay in the shared memo
                isl.alive = False
                MT.counter("island.kills").inc()
                self.events.append({"round": r, "island": isl.index,
                                    "event": "killed", "error": str(e)})
            isl.last_duration_s = time.monotonic() - t0
        self.round += 1

    def _trace_front(self, isl: Island, r: int) -> None:
        """Per-generation front stats into the trace (tracing-only: the
        rank over memoized objectives is recomputed here, never drawn from
        the RNG, so trajectories are identical with tracing on or off)."""
        if not TR.active():
            return
        h = isl.state.history[-1] if isl.state.history else {}
        objs = np.asarray([self.evaluations[s.to_json()]
                           for s in isl.state.population], float)
        # first front only, vectorized — the generic per-pair
        # non_dominated_sort would tax every traced generation
        first = pareto_front(objs)
        front = [[round(float(v), 6) for v in objs[int(i)]] for i in first]
        TR.event("ga.front", island=isl.index, round=r,
                 generation=isl.state.generation,
                 best_acc=h.get("best_acc"), min_cost=h.get("min_cost"),
                 front_size=len(front), front=front)

    # -- migration ----------------------------------------------------------

    def _migrate(self) -> None:
        alive = [isl for isl in self.islands if isl.alive]
        m = self.icfg.migrants
        if len(alive) < 2 or m <= 0:
            return
        # all ranks computed on pre-migration populations (simultaneous
        # exchange); populations are post-generation, so every member is
        # already in the shared memo — no new evaluations here
        ranked = {isl.index: GA.rank_population(
            self._fit_specs(isl.state.population)) for isl in alive}
        staged: Dict[int, List[ModelMin]] = {}
        for pos, src in enumerate(alive):
            dst = alive[(pos + 1) % len(alive)]
            elite = [src.state.population[j] for j in ranked[src.index][:m]]
            newpop = list(dst.state.population)
            # worst-ranked members of the receiver make room for the elites
            for slot, spec in zip(reversed(ranked[dst.index]), elite):
                newpop[slot] = spec
            staged[dst.index] = newpop
        for isl in alive:
            if isl.index in staged:
                isl.state = dataclasses.replace(isl.state,
                                                population=staged[isl.index])
        MT.counter("fleet.migrations").inc()
        MT.counter("fleet.migrants_accepted").inc(m * len(staged))
        self.events.append({"round": self.round, "event": "migration",
                            "migrants": m, "islands": len(alive)})
