"""Pallas population netlist-sim kernel.

Grid: (P candidates, B/block_b input tiles) — every cell owns one
candidate's whole dense node table (VMEM, (1, N) blocks) and one tile of
inputs. Levels run as an *unrolled scan* inside the kernel: per level l the
slot window [level_ptr[l], level_ptr[l+1]) is selected by an iota mask and
the whole table's candidate results are computed branchlessly (nested
``jnp.where`` over the opcode lane) — only in-window compute slots commit.
Within a level every operand slot lives in a strictly earlier level, so a
full-table masked update per level is dependency-safe.

Lanes are int32: ops.py routes populations whose verifier width bound
exceeds 32 to the jnp levels engine instead (TPU Pallas has no int64
lanes). Off-TPU the kernel runs interpret=True like the other five kernels
— the bit-exactness contract is identical in both modes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.circuit import ir
from repro.kernels import CompilerParams as _CompilerParams

_SHL = int(ir.Op.SHL)
_ADD = int(ir.Op.ADD)
_SUB = int(ir.Op.SUB)
_NEG = int(ir.Op.NEG)
_RELU = int(ir.Op.RELU)
_ARGMAX = int(ir.Op.ARGMAX)


def _sim_kernel(op_ref, a_ref, b_ref, sh_ref, val_ref, ptr_ref, inp_ref,
                am_ref, x_ref, o_ref, *, n_levels: int):
    N = op_ref.shape[1]
    bb = x_ref.shape[1]
    n_in = x_ref.shape[2]
    C = o_ref.shape[2]
    opc = op_ref[0, :]                                   # (N,)
    slot = jax.lax.broadcasted_iota(jnp.int32, (N,), 0)
    # executable slots: SHL..TRUNC minus ARGMAX (CONST/INPUT are seeds)
    is_comp = (opc >= _SHL) & (opc != _ARGMAX)

    # seed: CONST payloads everywhere (non-const slots carry 0), then the
    # ADC lanes — dynamic scalar columns via one-hot masks (n_in is small)
    vals = jnp.broadcast_to(val_ref[0, :][None, :], (bb, N)).astype(jnp.int32)
    for i in range(n_in):
        col = inp_ref[0, i]
        vals = jnp.where((slot == col)[None, :], x_ref[0, :, i][:, None],
                         vals)

    for lvl in range(n_levels):
        lo = ptr_ref[0, lvl]
        hi = ptr_ref[0, lvl + 1]
        a = jnp.take(vals, a_ref[0, :], axis=1)          # (bb, N)
        b = jnp.take(vals, b_ref[0, :], axis=1)
        sh = sh_ref[0, :][None, :]
        r = jnp.where(opc == _SHL, jnp.left_shift(a, sh),
            jnp.where(opc == _ADD, a + b,
            jnp.where(opc == _SUB, a - b,
            jnp.where(opc == _NEG, -a,
            jnp.where(opc == _RELU, jnp.maximum(a, 0),
                      # TRUNC: arithmetic floor-truncate of the low bits
                      jnp.left_shift(jnp.right_shift(a, sh), sh))))))
        active = is_comp & (slot >= lo) & (slot < hi)
        vals = jnp.where(active[None, :], r, vals)

    # the comparator tree's operand gather (C dynamic columns, one-hot)
    cols = []
    for j in range(C):
        col = am_ref[0, j]
        cols.append(jnp.sum(jnp.where((slot == col)[None, :], vals, 0),
                            axis=1))
    o_ref[0, :, :] = jnp.stack(cols, axis=1)


def netlist_sim_pallas(op, arg_a, arg_b, shift, val, level_ptr, input_pos,
                       argmax_pos, x, *, block_b: int = 256,
                       interpret: bool = False):
    """Tables: (P, N) int32 (``val`` included — int32 lanes only);
    level_ptr: (P, L+1); input_pos: (P, n_in); argmax_pos: (P, C);
    x: (P, B, n_in) int32 with B a multiple of block_b (ops.py pads).
    -> (P, B, C) int32 comparator operands."""
    P, N = op.shape
    Lp1 = level_ptr.shape[1]
    B, n_in = x.shape[1], x.shape[2]
    C = argmax_pos.shape[1]
    assert B % block_b == 0, (B, block_b)
    grid = (P, B // block_b)

    row = pl.BlockSpec((1, N), lambda p, t: (p, 0))
    return pl.pallas_call(
        functools.partial(_sim_kernel, n_levels=Lp1 - 1),
        grid=grid,
        in_specs=[
            row, row, row, row, row,
            pl.BlockSpec((1, Lp1), lambda p, t: (p, 0)),
            pl.BlockSpec((1, n_in), lambda p, t: (p, 0)),
            pl.BlockSpec((1, C), lambda p, t: (p, 0)),
            pl.BlockSpec((1, block_b, n_in), lambda p, t: (p, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_b, C), lambda p, t: (p, t, 0)),
        out_shape=jax.ShapeDtypeStruct((P, B, C), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(op, arg_a, arg_b, shift, val, level_ptr, input_pos, argmax_pos, x)
