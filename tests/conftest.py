"""Shared fixtures. NOTE: XLA_FLAGS device-count forcing must NOT be set here
(smoke tests and benches see the single real CPU device; only launch/dryrun.py
forces 512 placeholder devices, in its own process)."""
import os

# keep XLA quiet and single-threaded compile deterministic-ish on the 1-core box
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# ambient static analysis ON under the test suite: every PassManager run,
# every compiled netlist and every evaluated population is verified
# (repro.verify). Production sweeps leave REPRO_VERIFY unset and pay
# nothing. Export REPRO_VERIFY=0 to profile the unverified paths.
os.environ.setdefault("REPRO_VERIFY", "1")

import jax
import numpy as np
import pytest


def pytest_report_header(config):
    """Tier-1 must collect on a bare interpreter: property-based modules
    import hypothesis through tests/_hypothesis_compat.py, which downgrades
    @given tests to clean skips when it is absent."""
    try:
        import hypothesis
        return f"hypothesis: {hypothesis.__version__} (property tests active)"
    except ImportError:
        return ("hypothesis: NOT INSTALLED — property-based tests will be "
                "skipped (pip install -r requirements-dev.txt)")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
