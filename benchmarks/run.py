"""Benchmark harness: one function per paper table/figure + framework
benches. Prints ``name,us_per_call,derived`` CSV summaries at the end.

  fig1_standalone — paper Fig. 1 (standalone technique Pareto fronts)
  fig2_combined   — paper Fig. 2 (hardware-aware GA, combined techniques)
  area_table      — paper §III baseline circuit table
  kernel_bench    — per-kernel derived TPU roofline
  roofline_table  — §Roofline across all dry-run cells
  ga_bench        — GA hot path: serial vs batched population evaluation
  circuit_bench   — bespoke netlist compile / bit-exact sim / delay
  netlist_bench   — netlist-exact vs analytic GA generation (<=2x gate)
  approx_bench    — budgeted circuit approximation + approximation-GA
  search_bench    — island runtime: throughput / checkpoint / resume cost

``python -m benchmarks.run [--fast] [--only NAME] [--compare BASELINE]``

``--compare`` reads a previously-saved ``name,us_per_call,...`` CSV (e.g.
the committed ``benchmarks/baseline.csv``) and warns on every bench whose
wall-clock regressed more than 15% against it — names missing on either
side are skipped, so partial runs (``--only``) compare cleanly.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path
from typing import Dict

from benchmarks import approx_bench, area_table, circuit_bench, \
    dryrun_memory_table, fig1_standalone, fig2_combined, ga_bench, \
    kernel_bench, netlist_bench, roofline_table, search_bench

BENCHES = [
    ("area_table", area_table.main),
    ("fig1_standalone", fig1_standalone.main),
    ("fig2_combined", fig2_combined.main),
    ("kernel_bench", kernel_bench.main),
    ("roofline_table", roofline_table.main),
    ("dryrun_memory_table", dryrun_memory_table.main),
    ("ga_bench", ga_bench.main),
    ("circuit_bench", circuit_bench.main),
    ("netlist_bench", netlist_bench.main),
    ("approx_bench", approx_bench.main),
    ("search_bench", search_bench.main),
]


def load_baseline(path) -> Dict[str, float]:
    """``name,us_per_call[,...]`` CSV -> {name: us}. Header lines and
    unparsable rows are skipped."""
    out: Dict[str, float] = {}
    for line in Path(path).read_text().splitlines():
        parts = line.strip().split(",")
        if len(parts) < 2 or parts[0] == "name":
            continue
        try:
            out[parts[0]] = float(parts[1])
        except ValueError:
            continue
    return out


def compare_against(baseline: Dict[str, float], current: Dict[str, float],
                    threshold: float = 0.15) -> Dict[str, float]:
    """{name: relative slowdown} for benches slower than baseline by more
    than ``threshold`` (0.15 = 15%)."""
    return {name: us / baseline[name] - 1.0
            for name, us in current.items()
            if name in baseline and baseline[name] > 0
            and us > baseline[name] * (1.0 + threshold)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--compare", default=None, metavar="BASELINE_CSV",
                    help="warn on benches >15%% slower than this "
                         "name,us_per_call CSV")
    args = ap.parse_args()

    csv = []
    current: Dict[str, float] = {}
    for name, fn in BENCHES:
        if args.only and name != args.only:
            continue
        print(f"\n=== {name} {'=' * (60 - len(name))}")
        t0 = time.time()
        fn(fast=args.fast)
        us = (time.time() - t0) * 1e6
        current[name] = us
        csv.append(f"{name},{us:.0f},see-above")
    print("\nname,us_per_call,derived")
    for line in csv:
        print(line)

    if args.compare:
        regressions = compare_against(load_baseline(args.compare), current)
        for name, slow in sorted(regressions.items()):
            print(f"WARNING: {name} regressed {slow * 100:.0f}% vs "
                  f"{args.compare} (>15% threshold)")
        if not regressions:
            print(f"compare: no >15% regressions vs {args.compare}")


if __name__ == "__main__":
    main()
