"""Printed-MLP minimization pipeline (the paper, end to end).

Flow per candidate spec (bits/sparsity/clusters per layer):

  FP32 pretrain (cached per dataset)
    -> magnitude masks from pretrained weights (fixed during finetune)
    -> QAT finetune with STE prune/cluster/quant forward   [paper's QKeras QAT]
    -> bespoke "compile": integer weights + shared-product codebooks
    -> test accuracy of the compiled arithmetic + printed area (hw_model)

The standalone sweeps reproduce Fig. 1; `core.ga` drives the combined search
of Fig. 2 through `evaluate_spec`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.printed_mlp import PrintedMLPConfig
from repro.core import clustering as C
from repro.core import hw_model as HW
from repro.core import pruning as P
from repro.core import quantization as Q
from repro.core.compression_spec import LayerMin, ModelMin
from repro.data.uci import dataset_for
from repro.nn import mlp as M


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------


def _adam_update(g, m, v, t, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * jnp.square(g)
    mh = m / (1 - b1 ** t)
    vh = v / (1 - b2 ** t)
    return -lr * mh / (jnp.sqrt(vh) + eps), m, v


def _loss(params, x, y, w_transform):
    p2 = {"layers": tuple(
        {"w": w_transform(i, l["w"]), "b": l["b"]}
        for i, l in enumerate(params["layers"]))}
    logits = M.mlp_forward(p2, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))


def _train(params, x, y, *, epochs: int, lr: float, w_transform):
    flat, treedef = jax.tree_util.tree_flatten(params)
    m0 = [jnp.zeros_like(l) for l in flat]

    def epoch(carry, t):
        flat, m, v = carry
        params = jax.tree_util.tree_unflatten(treedef, flat)
        g = jax.grad(_loss)(params, x, y, w_transform)
        gflat = jax.tree_util.tree_leaves(g)
        upd = [_adam_update(gi, mi, vi, t + 1, lr)
               for gi, mi, vi in zip(gflat, m, v)]
        flat = [f + u[0] for f, u in zip(flat, upd)]
        return (flat, [u[1] for u in upd], [u[2] for u in upd]), None

    (flat, _, _), _ = jax.lax.scan(
        epoch, (flat, m0, list(m0)), jnp.arange(epochs, dtype=jnp.float32))
    return jax.tree_util.tree_unflatten(treedef, flat)


@functools.lru_cache(maxsize=32)
def pretrain(cfg: PrintedMLPConfig, *, epochs: int = 600, lr: float = 5e-3,
             seed: int = 0):
    """FP32 baseline training (cached). Returns (params, (data tuple))."""
    xtr, ytr, xte, yte = dataset_for(cfg, seed=seed)
    params = M.mlp_init(jax.random.PRNGKey(seed), cfg.layer_dims)
    fit = jax.jit(functools.partial(
        _train, epochs=epochs, lr=lr, w_transform=lambda i, w: w))
    params = fit(params, jnp.asarray(xtr), jnp.asarray(ytr))
    return params, (xtr, ytr, xte, yte)


# ---------------------------------------------------------------------------
# QAT finetune under a spec
# ---------------------------------------------------------------------------


def _qat_transform(spec: ModelMin, masks):
    def t(i, w):
        lm = spec.layers[i]
        if masks[i] is not None:
            w = P.apply_mask(w, masks[i])
        if lm.clusters is not None:
            w = C.cluster_ste(w, lm.clusters, per_input=True)
        if lm.bits is not None:
            w = Q.fake_quant(w, Q.QuantConfig(bits=lm.bits))
        return w
    return t


def qat_finetune(params0, spec: ModelMin, masks, x, y, *, epochs: int = 150,
                 lr: float = 2e-3):
    fit = jax.jit(functools.partial(
        _train, epochs=epochs, lr=lr, w_transform=_qat_transform(spec, masks)))
    return fit(params0, jnp.asarray(x), jnp.asarray(y))


# ---------------------------------------------------------------------------
# bespoke compile + evaluation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CompiledMLP:
    q_layers: List[np.ndarray]           # integer weights (0 = pruned)
    scales: List[float]
    biases: List[np.ndarray]
    clusters: List[Optional[Tuple[np.ndarray, np.ndarray]]]  # (idx, int codebook)
    w_bits: List[int]
    input_bits: int

    def dense_weights(self) -> List[np.ndarray]:
        out = []
        for q, s, cl in zip(self.q_layers, self.scales, self.clusters):
            out.append(q.astype(np.float32) * s)
        return out


def compile_bespoke(params, spec: ModelMin, masks) -> CompiledMLP:
    q_layers, scales, biases, clusters, w_bits = [], [], [], [], []
    for i, layer in enumerate(params["layers"]):
        lm = spec.layers[i]
        bits = lm.bits if lm.bits is not None else 8
        w = np.asarray(layer["w"], np.float32)
        if masks[i] is not None:
            w = w * np.asarray(masks[i], np.float32)
        if lm.clusters is not None:
            cb, idx = C.cluster_per_input(jnp.asarray(w), lm.clusters)
            cb, idx = np.asarray(cb), np.asarray(idx)
            w_rec = np.take_along_axis(cb, idx, axis=1)
            # snap codebooks to the fixed-point grid
            qmax = 2 ** (bits - 1) - 1
            s = max(np.abs(w_rec).max(), 1e-8) / qmax
            cb_q = np.clip(np.round(cb / s), -qmax, qmax).astype(np.int64)
            q = np.take_along_axis(cb_q, idx, axis=1)
            # re-apply pruning zeros (cluster may absorb them)
            if masks[i] is not None:
                q = q * np.asarray(masks[i], np.int64)
            clusters.append((idx, cb_q))
        else:
            qj, sj = Q.quantize_int(jnp.asarray(w), Q.QuantConfig(bits=bits))
            q, s = np.asarray(qj, np.int64), float(np.asarray(sj))
            clusters.append(None)
        q_layers.append(q)
        scales.append(float(s))
        biases.append(np.asarray(layer["b"], np.float32))
        w_bits.append(bits)
    return CompiledMLP(q_layers, scales, biases, clusters, w_bits,
                       spec.input_bits)


def quantize_inputs(c: CompiledMLP, x: np.ndarray) -> np.ndarray:
    """ADC front-end: features in [0, 1] -> unsigned integers on the
    2**input_bits - 1 grid — the same rounding `compiled_accuracy` applies
    before its float emulation, kept integer."""
    levels = (1 << c.input_bits) - 1
    return np.round(np.asarray(x, np.float32) * levels).astype(np.int64)


def integer_biases(c: CompiledMLP) -> List[np.ndarray]:
    """Bias constants on each layer's integer accumulator grid.

    Layer i's integer pre-activation represents the float one through the
    cumulative factor alpha_i = (prod_{j<=i} scale_j) / (2**input_bits - 1)
    (inputs contribute 1/levels, each weight matmul its layer scale), so the
    hardwired bias constant is round(b / alpha_i). ReLU and argmax commute
    with the positive alpha_i, making this the only rounding the bespoke
    integer circuit adds on top of the QAT compile."""
    alpha = 1.0 / ((1 << c.input_bits) - 1)
    out = []
    for i, (s, b) in enumerate(zip(c.scales, c.biases)):
        alpha *= s
        v = np.round(np.asarray(b, np.float64) / alpha)
        if np.abs(v).max(initial=0.0) >= 2.0 ** 62:
            raise OverflowError(
                f"layer {i} bias constant exceeds the 62-bit exact integer "
                f"budget (degenerate scale chain alpha={alpha:.3e})")
        out.append(v.astype(np.int64))
    return out


def integer_forward(c: CompiledMLP, x_int: np.ndarray
                    ) -> Tuple[List[np.ndarray], np.ndarray]:
    """The static QAT forward in exact integer arithmetic — the reference
    semantics the compiled netlist (`repro.circuit`) must reproduce
    bit-for-bit.

    x_int: (B, d_in) integers from `quantize_inputs`. Returns (per-layer
    integer pre-activations [(B, d_out_i) int64], argmax class (B,)).
    """
    b_ints = integer_biases(c)
    a = np.asarray(x_int, np.int64)
    pres: List[np.ndarray] = []
    for i, (q, b) in enumerate(zip(c.q_layers, b_ints)):
        pre = a @ q.astype(np.int64) + b
        pres.append(pre)
        if i < len(c.q_layers) - 1:
            a = np.maximum(pre, 0)
    return pres, np.argmax(pres[-1], axis=1)


def compiled_accuracy(c: CompiledMLP, x: np.ndarray, y: np.ndarray) -> float:
    """Accuracy of the exact bespoke arithmetic: quantized inputs x quantized
    integer weights (float emulation is exact for these ranges)."""
    levels = 2 ** c.input_bits - 1
    h = np.round(np.asarray(x, np.float32) * levels) / levels
    ws = c.dense_weights()
    for i, (w, b) in enumerate(zip(ws, c.biases)):
        h = h @ w + b
        if i < len(ws) - 1:
            h = np.maximum(h, 0.0)
    return float(np.mean(np.argmax(h, axis=1) == y))


def compiled_cost(c: CompiledMLP) -> HW.CircuitCost:
    return HW.mlp_cost(c.q_layers, w_bits=c.w_bits, in_bits=c.input_bits,
                       clusters=c.clusters)


# ---------------------------------------------------------------------------
# spec evaluation + sweeps (Fig. 1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EvalResult:
    spec: ModelMin
    accuracy: float
    area_mm2: float
    power_mw: float
    n_multipliers: int
    # critical-path length of the compiled netlist in full-adder-stage
    # delays (repro.circuit) — the analytic model cannot produce this;
    # None for results predating the circuit compiler (old caches).
    delay_levels: Optional[int] = None


def make_masks(params0, spec: ModelMin):
    return [P.magnitude_mask(l["w"], lm.sparsity) if lm.sparsity > 0 else None
            for l, lm in zip(params0["layers"], spec.layers)]


def evaluate_spec(cfg: PrintedMLPConfig, spec: ModelMin, *,
                  epochs: int = 150, seed: int = 0,
                  netlist: bool = True) -> EvalResult:
    """Serial single-spec evaluation, objective-identical to
    `batch_eval.evaluate_population`: accuracy defaults to the bit-exact
    simulation of the compiled netlist (the printed datapath); pass
    ``netlist=False`` for the analytic float-emulation opt-out.
    Area/power stay on the analytic pricing either way."""
    params0, (xtr, ytr, xte, yte) = pretrain(cfg, seed=seed)
    masks = make_masks(params0, spec)
    params = qat_finetune(params0, spec, masks, xtr, ytr, epochs=epochs)
    compiled = compile_bespoke(params, spec, masks)
    from repro.circuit import compile as CC     # lazy: circuit imports us
    net = CC.compile_netlist(compiled)
    if spec.has_approx:
        # the printed circuit is the approximated netlist — one shared
        # scoring policy with the batched path (`approx.evaluate_netlist`)
        from repro import approx as AX
        return AX.evaluate_netlist(net, compiled, spec, xte, yte)
    if netlist:
        from repro import circuit as CIRC
        acc = CIRC.netlist_accuracy(net, compiled, xte, yte)
    else:
        acc = compiled_accuracy(compiled, xte, yte)
    cost = compiled_cost(compiled)
    return EvalResult(spec, acc, cost.area_mm2, cost.power_mw,
                      cost.n_multipliers,
                      delay_levels=net.critical_path_levels())


def evaluate_specs(cfg: PrintedMLPConfig, specs: Sequence[ModelMin], *,
                   epochs: int = 150, seed: int = 0,
                   cache=None) -> List[EvalResult]:
    """Batched counterpart of `evaluate_spec`: the whole list is QAT-
    finetuned in one vmapped jit and priced in one vectorized hw_model
    call (see `core.batch_eval`). `cache` is an optional
    `batch_eval.EvalCache` for cross-run persistence."""
    from repro.core import batch_eval as BE      # lazy: avoids import cycle
    return BE.evaluate_population(cfg, specs, epochs=epochs, seed=seed,
                                  cache=cache)


def baseline(cfg: PrintedMLPConfig, *, seed: int = 0) -> EvalResult:
    """MICRO'20 un-minimized bespoke MLP: dense 8-bit fixed point."""
    n = len(cfg.layer_dims) - 1
    return evaluate_spec(cfg, ModelMin.uniform(n, bits=8), epochs=60,
                         seed=seed)


def quant_sweep(cfg, bits_range=None, *, epochs=150, seed=0):
    if bits_range is None:
        bits_range = range(2, 8)
    n = len(cfg.layer_dims) - 1
    return [evaluate_spec(cfg, ModelMin.uniform(n, bits=b), epochs=epochs,
                          seed=seed) for b in bits_range]


def prune_sweep(cfg, sparsities=(0.2, 0.3, 0.4, 0.5, 0.6), *, epochs=150,
                seed=0):
    n = len(cfg.layer_dims) - 1
    return [evaluate_spec(
        cfg, ModelMin.uniform(n, bits=8, sparsity=s), epochs=epochs,
        seed=seed) for s in sparsities]


def cluster_sweep(cfg, ks=(2, 3, 4, 6, 8), *, epochs=150, seed=0):
    n = len(cfg.layer_dims) - 1
    return [evaluate_spec(
        cfg, ModelMin.uniform(n, bits=8, clusters=k), epochs=epochs,
        seed=seed) for k in ks]
