"""End-to-end driver (deliverable b): train a ~100M-param qwen3-family LM for
a few hundred steps on the synthetic Markov stream, with async checkpointing
and resume. On this CPU container a full run takes tens of minutes; pass
--steps to shorten.

Run:  PYTHONPATH=src python examples/train_lm_100m.py [--steps 300]
"""
import argparse
import dataclasses

import jax

from repro.configs import ARCHS
from repro.configs.base import LayerSpec, Segment
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.nn import transformer as T
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def model_100m():
    """qwen3-family, ~100M params: 12L x d768 x ffn2560, 32k vocab."""
    base = ARCHS["qwen3-0.6b"]
    return dataclasses.replace(
        base, name="qwen3-100m", d_model=768, num_heads=12, num_kv_heads=4,
        head_dim=64, d_ff=2560, vocab_size=32768,
        segments=(Segment((LayerSpec("attn", "dense"),), 12),),
        dtype="float32", tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m_ckpt")
    args = ap.parse_args()

    cfg = model_100m()
    n = sum(int(x.size) for x in jax.tree_util.tree_leaves(
        jax.eval_shape(lambda k: T.init(k, cfg), jax.random.PRNGKey(0))))
    print(f"model: {cfg.name} {n/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq_len}")

    pipe = TokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch, branching=4))
    opt = AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps)
    trainer = Trainer(cfg, opt, TrainerConfig(
        total_steps=args.steps, ckpt_every=100, log_every=10,
        ckpt_dir=args.ckpt_dir, microbatch=None), pipe)
    trainer.install_signal_handler()
    out = trainer.run()
    first = trainer.history[0]["loss"]
    print(f"loss {first:.3f} -> {out['final_loss']:.3f} "
          f"({out['wall_s']:.0f}s; ckpts in {args.ckpt_dir})")
    assert out["final_loss"] < first, "loss must decrease"


if __name__ == "__main__":
    main()
