"""Circuit compiler benchmark: compile time, simulated throughput, delay.

For each printed-MLP dataset, two design points (the dense 8-bit MICRO'20
baseline and a minimized bits/sparsity/clusters spec) are lowered to their
bespoke netlists and measured:

* **compile**   — host-side lowering time (CompiledMLP -> validated netlist);
* **simulate**  — warm batched inferences/sec of the bit-exact netlist
  evaluator over the test set, against the dense float forward pass of the
  same weights (the gap is the price of gate-level exactness — the dense
  forward is one matmul chain, the netlist is thousands of scattered
  integer ops) and against the packed population engine
  (`repro.kernels.netlist_sim`, here at P=1 — the executable a whole GA
  population shares);
* **verify**    — bit-exactness vs `minimize.integer_forward` and the
  structural-vs-analytic cost cross-validation, asserted on every row;
* **delay**     — critical-path length in adder stages and the implied
  printed operating rate, the axis the analytic model cannot produce.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import circuit
from repro.configs.printed_mlp import PRINTED_MLPS
from repro.core import minimize as MZ
from repro.core.compression_spec import ModelMin
from repro.kernels import netlist_sim as NS
from repro.nn import mlp as M


def _bench_point(cfg, spec: ModelMin, *, seed: int = 0) -> Dict:
    params0, (_, _, xte, yte) = MZ.pretrain(cfg, seed=seed)
    masks = MZ.make_masks(params0, spec)
    compiled = MZ.compile_bespoke(params0, spec, masks)

    t = [0.0] * 3
    for i in range(3):
        t0 = time.perf_counter()
        net = circuit.compile_netlist(compiled)
        t[i] = time.perf_counter() - t0
    compile_ms = sorted(t)[1] * 1e3

    # bit-exactness + cost agreement are part of the bench contract
    xq = MZ.quantize_inputs(compiled, xte)
    sim = circuit.Simulator(net)
    out = sim.run(xq)
    ref_pre, ref_argmax = MZ.integer_forward(compiled, xq)
    exact = all(np.array_equal(a, b) for a, b in zip(out["pre"], ref_pre)) \
        and np.array_equal(out["argmax"], ref_argmax)
    cv = circuit.cross_validate(net, compiled)

    # warm throughput: netlist simulation vs dense float forward
    reps = 5
    t0 = time.perf_counter()
    for _ in range(reps):
        sim.run(xq)
    sim_ips = reps * len(xq) / (time.perf_counter() - t0)

    # packed population engine at P=1 (one shape-bucketed executable)
    pop = NS.pack_population([net])
    xq64 = np.asarray(xq, np.int64)
    packed = NS.simulate_population(pop, xq64)           # warm-up + compile
    exact &= np.array_equal(packed["argmax"][0], out["argmax"])
    t0 = time.perf_counter()
    for _ in range(reps):
        NS.simulate_population(pop, xq64)
    pop_ips = reps * len(xq) / (time.perf_counter() - t0)

    fwd = jax.jit(M.mlp_forward)
    pfloat = {"layers": tuple(
        {"w": jnp.asarray(w), "b": jnp.asarray(b)}
        for w, b in zip(compiled.dense_weights(), compiled.biases))}
    xj = jnp.asarray(xte)
    fwd(pfloat, xj).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        fwd(pfloat, xj).block_until_ready()
    dense_ips = reps * len(xte) / (time.perf_counter() - t0)

    sc = cv["structural"]
    return {
        "dataset": cfg.name, "spec": spec.to_json(), "nodes": len(net),
        "compile_ms": compile_ms, "sim_inf_per_s": sim_ips,
        "pop_inf_per_s": pop_ips, "dense_inf_per_s": dense_ips,
        "slowdown": dense_ips / max(sim_ips, 1e-9),
        "critical_path_levels": sc.critical_path_levels,
        "delay_ms": sc.delay_ms, "max_hz": sc.max_hz,
        "bit_exact": exact, "crossval_ok": cv["ok"],
    }


def run(datasets=None, *, seed: int = 0) -> List[Dict]:
    rows = []
    for name in (datasets or sorted(PRINTED_MLPS)):
        cfg = PRINTED_MLPS[name]
        n_layers = len(cfg.layer_dims) - 1
        for spec in (ModelMin.uniform(n_layers, bits=8,
                                      input_bits=cfg.input_bits),
                     ModelMin.uniform(n_layers, bits=4, sparsity=0.4,
                                      clusters=8,
                                      input_bits=cfg.input_bits)):
            rows.append(_bench_point(cfg, spec, seed=seed))
    return rows


def main(fast: bool = False):
    rows = run(["seeds", "whitewine"] if fast else None)
    print("circuit_bench (bespoke netlist: compile / simulate / verify / "
          "delay)")
    print("dataset,bits,nodes,compile_ms,sim_inf_s,pop_inf_s,dense_inf_s,"
          "cp_levels,delay_ms,max_hz,bit_exact,crossval_ok")
    ok = True
    for r in rows:
        spec = ModelMin.from_json(r["spec"])
        tag = (f"{spec.layers[0].bits}b"
               + (f"/s{spec.layers[0].sparsity}" if spec.layers[0].sparsity
                  else "")
               + (f"/k{spec.layers[0].clusters}" if spec.layers[0].clusters
                  else ""))
        print(f"{r['dataset']},{tag},{r['nodes']},{r['compile_ms']:.1f},"
              f"{r['sim_inf_per_s']:.0f},{r['pop_inf_per_s']:.0f},"
              f"{r['dense_inf_per_s']:.0f},"
              f"{r['critical_path_levels']},{r['delay_ms']:.0f},"
              f"{r['max_hz']:.1f},{r['bit_exact']},{r['crossval_ok']}")
        ok &= r["bit_exact"] and r["crossval_ok"]
    print(f"acceptance (bit-exact + cost agreement on every row): "
          f"{'PASS' if ok else 'FAIL'}")
    # a FAIL must fail the harness/CI run, not just print
    assert ok, "netlist bit-exactness / cost cross-validation regressed"
    return rows


if __name__ == "__main__":
    main()
