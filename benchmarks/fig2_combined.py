"""Paper Fig. 2: the hardware-aware GA combining quantization + pruning +
clustering on the WhiteWine classifier. Claim: the combination dominates the
standalone techniques, reaching up to ~8x area gain at <=5% accuracy loss.

The GA runs through the batched population engine (`core.batch_eval`): each
generation's uncached specs are QAT-finetuned in one vmapped jit and priced
in one vectorized hw_model pass; a persistent on-disk cache (``cache_dir``)
makes re-runs and resumed searches free.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np

from repro.configs.printed_mlp import PRINTED_MLPS
from repro.core import batch_eval as BE
from repro.core import minimize as MZ
from repro.core.compression_spec import LayerMin, ModelMin
from repro.core.ga import (ARGMAX_LSB_CHOICES, CSD_DROP_CHOICES,
                           LSB_CHOICES, GAConfig, run_nsga2)
from repro.core.pareto import gain_at_loss, pareto_front


def run(dataset: str = "whitewine", *, population=14, generations=7,
        epochs=90, seed=0, cache_dir: Optional[str] = None,
        netlist: bool = True, approx: bool = False) -> Dict:
    """Accuracy is scored by default on the bit-exact simulation of each
    candidate's compiled circuit (`repro.circuit`, batched for the whole
    population through `repro.kernels.netlist_sim`); ``netlist=False``
    opts out to the float emulation of the bespoke arithmetic.
    ``approx=True`` additionally lets
    the GA search the circuit-approximation genes (`repro.approx`:
    truncated-CSD coefficients, accumulator LSB truncation) and forces
    netlist-exact accuracy so exact and approximated candidates compete on
    the same simulated-datapath objective."""
    cfg = PRINTED_MLPS[dataset]
    base = MZ.baseline(cfg)
    n_layers = len(cfg.layer_dims) - 1
    netlist = netlist or approx

    cache = (BE.EvalCache(f"{cache_dir}/{dataset}_evals.json")
             if cache_dir else None)
    record: Dict[str, MZ.EvalResult] = {}
    batch_evaluate = BE.make_batch_evaluator(cfg, epochs=epochs, seed=seed,
                                             cache=cache, netlist=netlist,
                                             record=record)

    # seed the population with the best standalone configs (warm start);
    # seed specs carry the dataset's input width (run_nsga2 propagates it
    # into every random genome)
    ib = cfg.input_bits
    seeds = [ModelMin.uniform(n_layers, bits=4, input_bits=ib),
             ModelMin.uniform(n_layers, bits=3, sparsity=0.3, input_bits=ib),
             ModelMin.uniform(n_layers, bits=4, sparsity=0.4, clusters=8,
                              input_bits=ib)]
    ga_cfg = GAConfig(population=population, generations=generations,
                      seed=seed, input_bits=cfg.input_bits)
    if approx:
        import dataclasses
        ga_cfg = dataclasses.replace(ga_cfg,
                                     csd_drop_choices=CSD_DROP_CHOICES,
                                     lsb_choices=LSB_CHOICES,
                                     argmax_lsb_choices=ARGMAX_LSB_CHOICES)
        # warm-start the approximation axis from the minimized seed
        seeds.append(ModelMin.uniform(n_layers, bits=4, sparsity=0.4,
                                      clusters=8, csd_drop=1, lsb=2,
                                      input_bits=ib))
    res = run_nsga2(n_layers, None, ga_cfg,
                    seed_specs=seeds, batch_evaluate=batch_evaluate)
    pts = [(1.0 - o[0], o[1]) for o in res.objectives]
    gain = gain_at_loss(pts, baseline_acc=base.accuracy,
                        baseline_area=base.area_mm2, max_loss=0.05)
    front_idx = pareto_front(res.objectives)
    # every front member was evaluated through `record` — report the
    # compiled netlist's critical-path delay next to acc/area (the delay
    # axis only the circuit compiler can produce)
    front = [(round(pts[i][0], 4), round(pts[i][1], 1),
              record[res.population[i].to_json()].delay_levels,
              res.population[i].to_json()) for i in front_idx]
    return {
        "dataset": dataset,
        "baseline_acc": round(base.accuracy, 4),
        "baseline_area_mm2": round(base.area_mm2, 1),
        "combined_gain_at_5pct": round(gain, 2),
        "pareto_front": front,
        "history": res.history,
        "n_evaluations": len(res.evaluations),
        "evaluations": res.evaluations,      # spec json -> objective tuple
    }


def main(fast: bool = False, cache_dir: Optional[str] = None):
    t0 = time.time()
    kw = dict(population=8, generations=3, epochs=60) if fast else {}
    res = run(cache_dir=cache_dir, **kw)
    print("fig2_combined (GA over bits x sparsity x clusters, WhiteWine)")
    print(f"baseline acc={res['baseline_acc']:.3f} "
          f"area={res['baseline_area_mm2']/100:.1f} cm2")
    print(f"combined gain at <=5% loss: {res['combined_gain_at_5pct']:.2f}x "
          f"(paper: up to ~8x) over {res['n_evaluations']} evaluations")
    for acc, area, delay, spec in res["pareto_front"][:8]:
        print(f"  front: acc={acc:.3f} area={area/100:7.2f} cm2 "
              f"delay={delay:3d} stages  {spec}")
    print(f"[{time.time()-t0:.0f}s]")
    return res


if __name__ == "__main__":
    main()
