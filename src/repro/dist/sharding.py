"""Name-based sharding rules for every architecture in the registry.

The rule engine is deliberately simple: a parameter's *path* (joined with
"/") and trailing shape pick a template spec; any template axis whose mesh
size does not divide the corresponding dim falls back to replication. This
keeps the rules total — `param_specs` resolves every leaf of every arch or
the divisibility guard degrades it safely — which is what the 1000-chip
launch path needs (a partial rule table is a runtime crash on the pod).

Conventions (2D mesh ("data", "model"); a leading "pod" axis folds into the
batch axes):

* activations / batch:     sharded over all non-"model" axes;
* dense kernels (d, f):    fsdp on d ("data"), tensor-parallel on f ("model");
* attention projections:   heads on "model", d on "data" (q/k/v), reversed
  for the output projection;
* MoE experts:             expert axis on "model" (expert parallelism), d on
  "data";
* SSM / RG-LRU state dims: d_inner on "model";
* embeddings / lm_head:    vocab on "model";
* norms, gates, biases of norms: replicated.

Parameters stacked by the segment scan carry one leading ``repeats`` axis;
templates are right-aligned against the trailing dims, leading dims
replicate.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

DATA_AXIS = "data"
MODEL_AXIS = "model"


# ---------------------------------------------------------------------------
# paths
# ---------------------------------------------------------------------------


def path_str(path) -> str:
    """Join a jax key path into "a/b/0/c" form (used for rule matching and
    as the stable leaf identifier in checkpoint manifests)."""
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _axis_sizes(mesh) -> Dict[str, int]:
    return dict(mesh.shape)


def abstract_mesh(axis_sizes: Sequence[int], axis_names: Sequence[str]):
    """Version-portable AbstractMesh: jax >= 0.5 takes (sizes, names),
    0.4.x takes ((name, size), ...)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def batch_axes(mesh) -> Tuple[str, ...]:
    """All non-tensor-parallel mesh axes, in mesh order — the axes a global
    batch is sharded over (a "pod" super-axis composes with "data")."""
    return tuple(n for n in mesh.axis_names if n != MODEL_AXIS)


def batch_spec(mesh, ndim: int) -> P:
    """PartitionSpec for a batch-leading array: dim 0 over the batch axes,
    the rest replicated."""
    return P(batch_axes(mesh), *([None] * (ndim - 1)))


# ---------------------------------------------------------------------------
# rule templates
# ---------------------------------------------------------------------------

# Matched against the "/"-joined path, first hit wins. A template is the
# spec of the *trailing* dims of the leaf; leading (scan/stack) dims
# replicate. None = replicated dim.
_PARAM_RULES: Sequence[Tuple[Tuple[str, ...], Tuple]] = (
    # --- replicated small parameters ------------------------------------
    (("norm",), ()),                              # all norms incl. q/k/kv_norm
    (("cross_gate",), ()),
    (("router/kernel",), (DATA_AXIS, None)),      # router out dim replicated
    # --- embeddings ------------------------------------------------------
    (("pos_embed/table",), ()),
    (("embed/table",), (MODEL_AXIS, None)),
    (("lm_head/kernel",), (DATA_AXIS, MODEL_AXIS)),
    # --- MoE experts (E, d, de) / (E, de, d) -----------------------------
    (("experts/wi",), (MODEL_AXIS, DATA_AXIS, None)),
    (("experts/wo",), (MODEL_AXIS, None, DATA_AXIS)),
    # --- attention: (d, H, hd) in / (H, hd, d) out -----------------------
    (("wq/kernel", "wk/kernel", "wv/kernel",
      "c_wq/kernel", "c_wk/kernel", "c_wv/kernel"),
     (DATA_AXIS, MODEL_AXIS, None)),
    (("mixer/wo/kernel", "c_wo/kernel"), (MODEL_AXIS, None, DATA_AXIS)),
    # --- MLA (DeepSeek): low-rank down then per-head up ------------------
    (("w_dq/kernel", "w_dkv/kernel", "w_kr/kernel"), (DATA_AXIS, None)),
    (("w_uq/kernel", "w_uk/kernel", "w_uv/kernel"),
     (DATA_AXIS, MODEL_AXIS, None)),
    # --- dense MLP -------------------------------------------------------
    (("mlp/wi", "shared/wi"), (DATA_AXIS, MODEL_AXIS)),
    (("mlp/wo/kernel", "shared/wo/kernel"), (MODEL_AXIS, DATA_AXIS)),
    # --- Mamba SSM: d_inner is the TP dim --------------------------------
    (("in_proj/kernel",), (DATA_AXIS, MODEL_AXIS)),
    (("x_proj/kernel", "dt_proj/kernel"), (None, MODEL_AXIS)),
    (("out_proj/kernel",), (MODEL_AXIS, DATA_AXIS)),
    (("A_log",), (MODEL_AXIS, None)),
    (("mixer/D", "dt_proj/bias", "conv/bias"), (MODEL_AXIS,)),
    (("conv/kernel",), (None, MODEL_AXIS)),
    # --- RG-LRU (griffin): square d->d_inner gates, out proj back --------
    (("w_out/kernel",), (MODEL_AXIS, DATA_AXIS)),
    (("w_a/kernel", "w_i/kernel", "w_x/kernel", "w_gate/kernel"),
     (DATA_AXIS, MODEL_AXIS)),
    (("w_a/bias", "w_i/bias"), (MODEL_AXIS,)),
    (("Lambda",), (MODEL_AXIS,)),
)


def _template_for(path: str, shape) -> Tuple:
    for keys, tpl in _PARAM_RULES:
        if any(k in path for k in keys):
            return tpl
    # generic fallback: shard the two trailing dims of big matrices
    if len(shape) >= 2:
        return (DATA_AXIS, MODEL_AXIS)
    return ()


def _guard(tpl: Tuple, shape, sizes: Dict[str, int]) -> P:
    """Right-align the template on `shape`; drop any axis that does not
    divide its dim. Returns a full-rank PartitionSpec."""
    spec = [None] * len(shape)
    if len(tpl) > len(shape):          # scalar/bias narrower than template
        tpl = tpl[-len(shape):] if len(shape) else ()
    off = len(shape) - len(tpl)
    for i, ax in enumerate(tpl):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        total = 1
        for a in axes:
            total *= sizes[a]
        if shape[off + i] % total == 0 and total > 1:
            spec[off + i] = ax
    # all-None spec of a norm/bias collapses to P() (cosmetic, equivalent)
    if all(s is None for s in spec) and len(tpl) == 0:
        return P()
    return P(*spec)


def param_specs(params, mesh):
    """PartitionSpec pytree for a parameter tree (same structure, P leaves).
    Resolves on abstract leaves — only `.shape` is read."""
    sizes = _axis_sizes(mesh)

    def rule(path, leaf):
        return _guard(_template_for(path_str(path), leaf.shape),
                      leaf.shape, sizes)

    return jax.tree_util.tree_map_with_path(rule, params)


# ---------------------------------------------------------------------------
# decode-cache rules
# ---------------------------------------------------------------------------


def cache_specs(state, mesh, cfg=None, *, shard_batch: bool = True):
    """Specs for a decode-state pytree (stacked caches + counters).
    `cfg` (optional ArchConfig) is accepted for call-site symmetry with
    `param_specs`; the rules below resolve from shapes alone.

    KV caches (repeats, B, S, KV, hd): batch over the batch axes, then KV
    heads on "model" when divisible, else the sequence dim. SSM/RG-LRU
    state (.../h, .../conv): d_inner on "model". Counters replicate.
    """
    sizes = _axis_sizes(mesh)
    model = sizes.get(MODEL_AXIS, 1)
    baxes = batch_axes(mesh)
    btotal = 1
    for a in baxes:
        btotal *= sizes[a]

    def bspec(batch_dim_size):
        if shard_batch and btotal > 1 and batch_dim_size % btotal == 0:
            return baxes
        return None

    def rule(path, leaf):
        p = path_str(path)
        shape = leaf.shape
        if leaf.ndim == 0 or p.endswith("kv_len"):
            return P()
        if p.endswith("/k") or p.endswith("/v"):
            rep, B, S, KV, hd = shape
            if KV % model == 0 and model > 1:
                return P(None, bspec(B), None, MODEL_AXIS, None)
            if S % model == 0 and model > 1:
                return P(None, bspec(B), MODEL_AXIS, None, None)
            return P(None, bspec(B), None, None, None)
        if p.endswith("/h"):              # (rep, B, d_inner[, state])
            spec = [None, bspec(shape[1])] + [None] * (leaf.ndim - 2)
            if shape[2] % model == 0 and model > 1:
                spec[2] = MODEL_AXIS
            return P(*spec)
        if p.endswith("/conv"):           # (rep, B, width, d_inner)
            spec = [None, bspec(shape[1]), None, None]
            if shape[3] % model == 0 and model > 1:
                spec[3] = MODEL_AXIS
            return P(*spec)
        if p.endswith("c_kv") or p.endswith("k_rope"):  # MLA (rep, B, S, r)
            return P(None, bspec(shape[1]), *([None] * (leaf.ndim - 2)))
        if p.endswith("enc_out"):         # (B, T, d)
            return P(bspec(shape[0]), *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(rule, state)


def named_shardings(specs, mesh):
    """Materialize a spec pytree into NamedShardings on a concrete mesh."""
    from jax.sharding import NamedSharding
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
