"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block = two branches from the input:
  gate branch:      W_gate -> GeLU
  recurrent branch: W_x -> causal depthwise conv (K=4) -> RG-LRU
output = (lru_out * gelu(gate)) @ W_out

RG-LRU recurrence (all elementwise over lru_width):
  r_t = sigmoid(x_t W_a + b_a)               recurrence gate
  i_t = sigmoid(x_t W_i + b_i)               input gate
  log a_t = -c * r_t * softplus(Lambda)      (a = sigmoid(Lambda) ^ (c r_t))
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RGLRUConfig
from repro.nn import layers as L
from repro.nn.ssm import _causal_conv


def _width(cfg: ArchConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def rglru_init(key, cfg: ArchConfig, dtype):
    r: RGLRUConfig = cfg.rglru
    w = _width(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    # Lambda init so a = sigmoid(Lambda) is in [0.9, 0.999]
    u = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u) - jnp.log1p(-u)
    return {
        "w_x": L.dense_init(ks[0], d, w, dtype),
        "w_gate": L.dense_init(ks[1], d, w, dtype),
        "conv": {"kernel": L._trunc_normal(ks[2], (r.d_conv, w),
                                           1.0 / math.sqrt(r.d_conv), dtype),
                 "bias": jnp.zeros((w,), dtype)},
        "w_a": {"kernel": L._trunc_normal(ks[3], (w, w), w ** -0.5, dtype),
                "bias": jnp.zeros((w,), jnp.float32)},
        "w_i": {"kernel": L._trunc_normal(ks[5], (w, w), w ** -0.5, dtype),
                "bias": jnp.zeros((w,), jnp.float32)},
        "Lambda": lam,
        "w_out": L.dense_init(ks[0], w, d, dtype),
    }


def _rglru_scan(x, r_gate, i_gate, lam, c, h0):
    """x/r_gate/i_gate: (B,T,w) fp32; returns y (B,T,w), h_last (B,w)."""
    log_a = -c * r_gate * jax.nn.softplus(lam)[None, None]     # (B,T,w), <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated = beta * (i_gate * x)

    def step(h, xs):
        a_t, g_t = xs
        h = a_t * h + g_t
        return h, h

    h_last, ys = jax.lax.scan(
        step, h0, (a.transpose(1, 0, 2), gated.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2), h_last


def rglru_apply(p, x, cfg: ArchConfig, *, cache=None):
    """x: (B,T,d). Returns (out, new_cache).

    cache (decode): {"conv": (B, K-1, w), "h": (B, w)}.
    """
    r: RGLRUConfig = cfg.rglru
    gate = jax.nn.gelu(L.dense_apply(p["w_gate"], x), approximate=True)
    xb = L.dense_apply(p["w_x"], x)
    conv_state = cache["conv"] if cache is not None else None
    xb, new_conv = _causal_conv(xb, p["conv"]["kernel"], p["conv"]["bias"],
                                state=conv_state)
    xf = xb.astype(jnp.float32)
    r_gate = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", xf, p["w_a"]["kernel"]
                                       .astype(jnp.float32)) + p["w_a"]["bias"])
    i_gate = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", xf, p["w_i"]["kernel"]
                                       .astype(jnp.float32)) + p["w_i"]["bias"])

    h0 = cache["h"] if cache is not None else \
        jnp.zeros((x.shape[0], xf.shape[-1]), jnp.float32)
    if cache is not None and x.shape[1] == 1:
        log_a = -r.c_exponent * r_gate[:, 0] * jax.nn.softplus(p["Lambda"])[None]
        a = jnp.exp(log_a)
        beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
        h = a * h0 + beta * (i_gate[:, 0] * xf[:, 0])
        y = h[:, None]
        new_cache = {"conv": new_conv, "h": h}
    else:
        y, h_last = _rglru_scan(xf, r_gate, i_gate, p["Lambda"],
                                r.c_exponent, h0)
        new_cache = None if cache is None else {"conv": new_conv, "h": h_last}

    out = y.astype(x.dtype) * gate
    return L.dense_apply(p["w_out"], out), new_cache


def make_rglru_cache(cfg: ArchConfig, batch: int, dtype):
    w = _width(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.rglru.d_conv - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }
