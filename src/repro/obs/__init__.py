"""Search-runtime observability: ambient tracing, metrics, run reports.

Three pieces, all zero-cost when off (the ``REPRO_TRACE`` idiom, mirroring
``REPRO_VERIFY``):

* `repro.obs.trace` — nestable host-side spans and structured events,
  appended as torn-write-safe JSONL;
* `repro.obs.metrics` — the process-wide counter/gauge/histogram registry,
  snapshotted into every search checkpoint and restored bit-identically on
  resume;
* `repro.obs.report` — ``python -m repro.obs.report trace.jsonl`` renders
  wall-clock breakdowns, per-island timelines, Pareto progress, cache-hit
  curves and the fault/quarantine ledger (plus CSVs).

`repro.obs.ring.RingLog` is the bounded in-memory event log the search
runtime uses so long runs spill their full event stream to the trace
instead of growing lists without bound.
"""
from repro.obs import metrics
from repro.obs.ring import RingLog
from repro.obs.trace import (active, capture, event, first_call, read_trace,
                             span, start, stop)

__all__ = ["RingLog", "active", "capture", "event", "first_call",
           "metrics", "read_trace", "span", "start", "stop"]
