"""Seeded-corruption catalog: known netlist breakages the verifier must
catch, one mutator per invariant class.

Each entry deliberately violates exactly one documented invariant of the
IR (wrong interval, dangling argument, duplicated constant, stale
bookkeeping, ...) while keeping everything else intact — so the tests can
assert not just "a diagnostic fired" but "the *right rule* fired". This is
the acceptance bar of the verification layer: 100% of this catalog
detected, 0 diagnostics on honest compiler/pass outputs.

Mutators operate on a deep copy (`apply_mutation`) and return ``False``
when the netlist lacks the feature they corrupt (e.g. no TRUNC node in an
exact netlist) — the test harness skips those.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Callable, Dict, FrozenSet, Optional, Tuple

from repro.circuit import ir


def _first(net: ir.Netlist, pred) -> Optional[ir.Node]:
    return next((n for n in net.nodes if pred(n)), None)


def _wrong_interval(net: ir.Netlist) -> bool:
    n = _first(net, lambda n: n.args)
    if n is None:
        return False
    n.hi += 1
    return True


def _dangling_arg(net: ir.Netlist) -> bool:
    n = _first(net, lambda n: n.args)
    if n is None:
        return False
    n.args = (len(net.nodes) + 5,) + n.args[1:]
    return True


def _cycle(net: ir.Netlist) -> bool:
    n = _first(net, lambda n: n.args)
    if n is None:
        return False
    n.args = (n.id,) + n.args[1:]          # self-reference = 1-cycle
    return True


def _stale_err(net: ir.Netlist) -> bool:
    n = _first(net, lambda n: n.op == ir.Op.INPUT)
    if n is None:
        return False
    n.err_lo, n.err_hi = -3, 0             # the ADC is exact by definition
    return True


def _empty_err_interval(net: ir.Netlist) -> bool:
    n = _first(net, lambda n: n.op == ir.Op.ADD)
    if n is None:
        return False
    n.err_lo, n.err_hi = 1, -1
    return True


def _bad_arity(net: ir.Netlist) -> bool:
    n = _first(net, lambda n: n.op == ir.Op.ADD)
    if n is None:
        return False
    n.args = n.args[:1]
    return True


def _dup_const(net: ir.Netlist) -> bool:
    c = _first(net, lambda n: n.op == ir.Op.CONST)
    if c is None:
        return False
    net.nodes.append(ir.Node(len(net.nodes), ir.Op.CONST, value=c.value,
                             lo=c.value, hi=c.value))
    return True


def _argmax_consumed(net: ir.Netlist) -> bool:
    if net.argmax_id is None:
        return False
    am = net.nodes[net.argmax_id]
    net.nodes.append(ir.Node(len(net.nodes), ir.Op.SHL, (am.id,), shift=0,
                             lo=am.lo, hi=am.hi, role=ir.ROLE_MULT))
    return True


def _stale_argmax_id(net: ir.Netlist) -> bool:
    n = _first(net, lambda n: n.op == ir.Op.ADD)
    if n is None or net.argmax_id is None:
        return False
    net.argmax_id = n.id
    return True


def _output_mismatch(net: ir.Netlist) -> bool:
    if len(net.output_ids) < 2:
        return False
    net.output_ids = net.output_ids[:-1]
    return True


def _unregistered_input(net: ir.Netlist) -> bool:
    if not net.input_ids:
        return False
    net.input_ids = net.input_ids[:-1]
    return True


def _negative_shift(net: ir.Netlist) -> bool:
    n = _first(net, lambda n: n.op == ir.Op.SHL)
    if n is None:
        return False
    n.shift = -1
    return True


def _identity_trunc(net: ir.Netlist) -> bool:
    n = _first(net, lambda n: n.op == ir.Op.TRUNC)
    if n is None:
        return False
    n.shift = 0                            # identity must not be a node
    return True


def _width_bomb(net: ir.Netlist) -> bool:
    c = _first(net, lambda n: n.op == ir.Op.CONST)
    if c is None:
        return False
    v = 1 << 70                            # past the 62-bit sim budget
    c.value, c.lo, c.hi = v, v, v
    return True


def _bad_role(net: ir.Netlist) -> bool:
    n = _first(net, lambda n: n.op == ir.Op.RELU)
    if n is None:
        return False
    n.role = ir.ROLE_MULT
    return True


def _trunc_provenance(net: ir.Netlist) -> bool:
    n = _first(net, lambda n: n.op == ir.Op.TRUNC)
    if n is None:
        return False
    n.role = ir.ROLE_TREE                  # not an approximation site
    return True


def _pre_node_swap(net: ir.Netlist) -> bool:
    if len(net.layer_pre_ids) < 2 or not net.layer_pre_ids[0]:
        return False
    p = net.layer_pre_ids[0][0]
    n = net.nodes[p]
    if not n.args:
        return False
    net.layer_pre_ids[0] = [n.args[0]] + net.layer_pre_ids[0][1:]
    return True


def _dead_code(net: ir.Netlist) -> bool:
    if not net.input_ids:
        return False
    src = net.nodes[net.input_ids[0]]
    net.nodes.append(ir.Node(len(net.nodes), ir.Op.NEG, (src.id,),
                             lo=-src.hi, hi=-src.lo, role=ir.ROLE_MULT,
                             layer=0, unit=(0, 0)))
    return True


@dataclasses.dataclass(frozen=True)
class Mutation:
    name: str
    apply: Callable[[ir.Netlist], bool]
    rules: FrozenSet[str]                  # rules allowed to catch it
    strict_only: bool = False              # caught only under strict mode
    needs_dce: bool = False                # caught only under expect_dce


CATALOG: Tuple[Mutation, ...] = (
    Mutation("wrong-interval", _wrong_interval, frozenset({"interval"})),
    Mutation("dangling-arg", _dangling_arg, frozenset({"topo"})),
    Mutation("cycle", _cycle, frozenset({"topo"})),
    Mutation("stale-err", _stale_err, frozenset({"err"})),
    Mutation("empty-err-interval", _empty_err_interval, frozenset({"err"})),
    Mutation("bad-arity", _bad_arity, frozenset({"arity"})),
    Mutation("dup-const", _dup_const,
             frozenset({"const-dedup", "dead-code"})),
    Mutation("argmax-consumed", _argmax_consumed, frozenset({"argmax"})),
    Mutation("stale-argmax-id", _stale_argmax_id, frozenset({"argmax"})),
    Mutation("output-mismatch", _output_mismatch,
             frozenset({"bookkeeping"})),
    Mutation("unregistered-input", _unregistered_input,
             frozenset({"bookkeeping"})),
    Mutation("negative-shift", _negative_shift,
             frozenset({"shift", "interval"})),
    Mutation("identity-trunc", _identity_trunc,
             frozenset({"shift", "interval"})),
    Mutation("width-bomb", _width_bomb, frozenset({"width-budget"})),
    Mutation("bad-role", _bad_role, frozenset({"role"}), strict_only=True),
    Mutation("trunc-provenance", _trunc_provenance,
             frozenset({"trunc-prov", "role"}), strict_only=True),
    Mutation("pre-node-swap", _pre_node_swap, frozenset({"pre-node"}),
             strict_only=True),
    Mutation("dead-code", _dead_code, frozenset({"dead-code"}),
             needs_dce=True),
)


def apply_mutation(net: ir.Netlist, m: Mutation) -> Optional[ir.Netlist]:
    """Deep-copy ``net`` and apply one catalog mutation. Returns the
    corrupted copy, or None when the mutation does not apply."""
    mutant = copy.deepcopy(net)
    return mutant if m.apply(mutant) else None
