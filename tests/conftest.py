"""Shared fixtures. NOTE: XLA_FLAGS device-count forcing must NOT be set here
(smoke tests and benches see the single real CPU device; only launch/dryrun.py
forces 512 placeholder devices, in its own process)."""
import os

# keep XLA quiet and single-threaded compile deterministic-ish on the 1-core box
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
