"""jaxlint (tools/jaxlint.py) tests: the whole src/ tree is clean (the CI
static-analysis gate, enforced here too so a hazard fails fast locally),
and each rule family fires on a minimal reproducer."""
import importlib.util
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "jaxlint", REPO / "tools" / "jaxlint.py")
jaxlint = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(jaxlint)


def _lint_src(src: str, tmp_path, name="mod.py"):
    f = tmp_path / name
    f.write_text(src)
    return jaxlint.lint_file(f, rel=name)


def _rules(findings):
    return [f.rule for f in findings]


def test_src_tree_is_clean():
    assert jaxlint.lint_paths([str(REPO / "src")]) == []


def test_int_domain_purity(tmp_path):
    src = ("import numpy as np\n"
           "from jax import numpy as jnp\n"
           "def f(a, b):\n"
           "    return a / b\n")
    findings = _lint_src(src, tmp_path, name="repro/circuit/ir.py".replace(
        "/", "_"))
    # not an int-domain module name -> nothing fires
    assert findings == []
    f = tmp_path / "repro" / "circuit"
    f.mkdir(parents=True)
    (f / "ir.py").write_text(src)
    findings = jaxlint.lint_paths([str(tmp_path)])
    assert sorted(set(_rules(findings))) == ["int-domain"]
    assert len(findings) == 3            # numpy import, jax import, '/'


def test_tracer_branch_and_numpy_in_jit(tmp_path):
    src = ("import functools\n"
           "import jax\n"
           "import numpy as np\n"
           "@functools.partial(jax.jit, static_argnames=('k',))\n"
           "def f(x, y, *, k=2):\n"
           "    if k > 1:\n"              # static: fine
           "        pass\n"
           "    pad = x if k else y\n"
           "    if y > 0:\n"              # tracer: flagged
           "        x = x + 1\n"
           "    while x:\n"               # tracer: flagged
           "        break\n"
           "    return np.sum(x)\n")      # numpy on tracer: flagged
    findings = _lint_src(src, tmp_path)
    assert _rules(findings) == ["tracer-branch", "tracer-branch",
                                "numpy-in-jit"]


def test_shape_derived_locals_not_flagged(tmp_path):
    # the kernels' idiom: branch on static params and shape-derived locals
    src = ("import functools\n"
           "import jax\n"
           "@functools.partial(jax.jit, static_argnames=('interpret',))\n"
           "def f(q, *, interpret=None):\n"
           "    if interpret is None:\n"
           "        interpret = True\n"
           "    T = q.shape[0]\n"
           "    padT = (-T) % 8\n"
           "    if padT:\n"
           "        q = q * 1\n"
           "    return q\n")
    assert _lint_src(src, tmp_path) == []


def test_static_argnames_hygiene(tmp_path):
    src = ("import functools\n"
           "import jax\n"
           "@functools.partial(jax.jit, static_argnames=('ghost', 'opts'))\n"
           "def f(x, *, opts=[1]):\n"
           "    return x\n")
    findings = _lint_src(src, tmp_path)
    assert _rules(findings) == ["static-argnames", "static-argnames"]
    assert "ghost" in findings[0].message
    assert "opts" in findings[1].message


def test_nested_defs_inside_jit_are_scanned(tmp_path):
    src = ("import jax\n"
           "@jax.jit\n"
           "def f(x, y):\n"
           "    def inner(z):\n"
           "        if y:\n"              # outer tracer used in nested def
           "            return z\n"
           "        return z + 1\n"
           "    return inner(x)\n")
    assert _rules(_lint_src(src, tmp_path)) == ["tracer-branch"]


def test_cli_exit_codes(tmp_path, capsys):
    good = tmp_path / "ok.py"
    good.write_text("x = 1\n")
    assert jaxlint.main([str(good)]) == 0
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\n@jax.jit\ndef f(a):\n    if a:\n"
                   "        return 1\n    return 0\n")
    assert jaxlint.main([str(bad)]) == 1
    assert jaxlint.main([]) == 2
    capsys.readouterr()


def test_obs_in_jit_flagged(tmp_path):
    src = ("import functools\n"
           "import jax\n"
           "from repro.obs import trace as TR\n"
           "from repro.obs import metrics as MT\n"
           "from repro.obs.trace import span\n"
           "@functools.partial(jax.jit, static_argnames=('n',))\n"
           "def f(x, *, n=2):\n"
           "    with TR.span('bad'):\n"
           "        MT.counter('c').inc()\n"
           "    span('also bad')\n"
           "    return x\n")
    findings = _lint_src(src, tmp_path)
    assert _rules(findings) == ["obs-in-jit"] * 3
    assert "host-side" in findings[0].message


def test_obs_outside_jit_not_flagged(tmp_path):
    src = ("import jax\n"
           "from repro.obs import trace as TR\n"
           "@jax.jit\n"
           "def _f_jit(x):\n"
           "    return x + 1\n"
           "def f(x):\n"
           "    with TR.span('kernels.f'):\n"      # dispatch span: fine
           "        y = _f_jit(x)\n"
           "    return y\n")
    assert _lint_src(src, tmp_path) == []


def test_jit_in_loop_construction_flagged(tmp_path):
    src = ("import jax\n"
           "def f(xs):\n"
           "    out = []\n"
           "    for x in xs:\n"
           "        g = jax.jit(lambda v: v + 1)\n"   # fresh wrapper/iter
           "        out.append(g(x))\n"
           "    return out\n")
    findings = _lint_src(src, tmp_path)
    assert _rules(findings) == ["jit-in-loop"]
    assert "inside a loop body" in findings[0].message


def test_jit_in_loop_partial_in_while_flagged(tmp_path):
    src = ("import functools\n"
           "import jax\n"
           "def f(x):\n"
           "    while x < 3:\n"
           "        h = functools.partial(jax.jit, static_argnames=('k',))\n"
           "        x = x + 1\n"
           "    return x\n")
    assert _rules(_lint_src(src, tmp_path)) == ["jit-in-loop"]


def test_jit_construct_and_dispatch_in_function_flagged(tmp_path):
    # the clustering.cluster_per_input hazard class this PR fixed: an
    # entry point that builds and invokes the jit per call never hits the
    # wrapper's dispatch cache
    src = ("import jax\n"
           "def cluster(w, k):\n"
           "    return jax.jit(_kmeans)(w, k)\n")
    findings = _lint_src(src, tmp_path)
    assert _rules(findings) == ["jit-in-loop"]
    assert "retraces and recompiles" in findings[0].message


def test_jit_hoisted_idioms_not_flagged(tmp_path):
    # construct-once / cached constructions: module scope, decorator,
    # lru_cache factory, attribute caching — and the repo's entry-point
    # idiom of *dispatching* a module-level jit inside a loop
    src = ("import functools\n"
           "import jax\n"
           "_g = jax.jit(lambda v: v + 1)\n"
           "@functools.partial(jax.jit, static_argnames=('k',))\n"
           "def _f_jit(x, *, k=2):\n"
           "    return x * k\n"
           "@functools.lru_cache(maxsize=None)\n"
           "def _make(k):\n"
           "    return jax.jit(lambda v: v * k)\n"
           "class Sim:\n"
           "    def __init__(self):\n"
           "        self._step = jax.jit(self._raw)\n"
           "    def _raw(self, x):\n"
           "        return x\n"
           "def run(xs):\n"
           "    out = []\n"
           "    for x in xs:\n"                  # dispatch in loop: fine
           "        out.append(_f_jit(x, k=3))\n"
           "        out.append(_g(x))\n"
           "    return out\n")
    assert _lint_src(src, tmp_path) == []
