"""Compression specifications — the genome of the hardware-aware search.

A :class:`LayerMin` is the per-layer minimization choice (quantization bits,
pruning sparsity, cluster count); a :class:`ModelMin` is one choice per
compressible layer. The same spec drives:

* the printed-MLP path (`core.minimize`): QAT retraining + bespoke compile +
  printed-area objective (the paper, faithfully);
* the LM path (`core.lm_compress` / examples): weight-pytree transforms +
  TPU roofline objective (`core.tpu_cost`) — the beyond-paper integration.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import clustering as C
from repro.core import pruning as P
from repro.core import quantization as Q


@dataclasses.dataclass(frozen=True)
class LayerMin:
    bits: Optional[int] = None         # None = full precision
    sparsity: float = 0.0
    clusters: Optional[int] = None     # None = no clustering

    def validate(self):
        assert self.bits is None or 2 <= self.bits <= 8, self.bits
        assert 0.0 <= self.sparsity <= 0.9, self.sparsity
        assert self.clusters is None or 2 <= self.clusters <= 64


@dataclasses.dataclass(frozen=True)
class ModelMin:
    layers: Tuple[LayerMin, ...]
    input_bits: int = 8

    def validate(self):
        for l in self.layers:
            l.validate()

    def to_json(self) -> str:
        return json.dumps({
            "input_bits": self.input_bits,
            "layers": [dataclasses.asdict(l) for l in self.layers]})

    @staticmethod
    def from_json(s: str) -> "ModelMin":
        d = json.loads(s)
        return ModelMin(tuple(LayerMin(**l) for l in d["layers"]),
                        d["input_bits"])

    @staticmethod
    def uniform(n_layers: int, *, bits=None, sparsity=0.0, clusters=None,
                input_bits: int = 8) -> "ModelMin":
        return ModelMin(tuple(LayerMin(bits, sparsity, clusters)
                              for _ in range(n_layers)), input_bits)


def qat_weight(w: jnp.ndarray, spec: LayerMin, mask=None) -> jnp.ndarray:
    """QAT forward transform (prune -> cluster -> quantize), all STE.
    Order matters: the bespoke circuit hardwires quantized shared products of
    surviving connections, so quantization is the outermost grid snap."""
    if mask is not None:
        w = P.apply_mask(w, mask)
    if spec.clusters is not None and w.ndim == 2:
        w = C.cluster_ste(w, spec.clusters, per_input=True)
    if spec.bits is not None:
        w = Q.fake_quant(w, Q.QuantConfig(bits=spec.bits))
    return w
