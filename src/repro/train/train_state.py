"""Train state + the canonical train_step / serve_step used by the trainer,
the launcher and the multi-pod dry-run."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn import transformer as T
from repro.train import losses
from repro.train.optimizer import (AdamWConfig, AdamWState, adamw_init,
                                   adamw_update)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_state(key, cfg: ArchConfig, opt_cfg: AdamWConfig) -> TrainState:
    params = T.init(key, cfg)
    return TrainState(params=params, opt=adamw_init(params))


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, *,
                    remat: bool = True, microbatch: Optional[int] = None,
                    compression=None, unroll: bool = False):
    """Returns train_step(state, batch) -> (state, metrics).

    microbatch: if set, gradient accumulation over batch slices (sequential
    lax.scan) — the standard large-scale memory lever.
    compression: optional `repro.core` QAT hook: params -> params applied to
    the forward pass only (the paper's technique as a first-class feature).
    """

    def loss_fn(params, batch):
        fwd_params = compression(params) if compression is not None else params
        logits, aux = T.forward(fwd_params, batch, cfg, remat=remat,
                                unroll=unroll)
        return losses.next_token_loss(logits, batch["tokens"], aux=aux)

    def grads_of(params, batch):
        if microbatch is None:
            return jax.value_and_grad(loss_fn)(params, batch)
        B = batch["tokens"].shape[0]
        assert B % microbatch == 0, (B, microbatch)
        n = B // microbatch
        slices = jax.tree_util.tree_map(
            lambda x: x.reshape((n, microbatch) + x.shape[1:]), batch)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(a.dtype), g_acc, g)
            return (loss_acc + loss, g_acc), None

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, g), _ = jax.lax.scan(body, (jnp.zeros(()), g0), slices)
        g = jax.tree_util.tree_map(lambda x: x / n, g)
        return loss / n, g

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        loss, grads = grads_of(state.params, batch)
        params, opt, metrics = adamw_update(opt_cfg, grads, state.opt,
                                            state.params)
        metrics = dict(metrics, loss=loss)
        return TrainState(params, opt), metrics

    return train_step


def make_serve_step(cfg: ArchConfig, *, unroll: bool = False):
    """serve_step(params, state, tokens) -> (next_tokens, state).
    One new token per request against the persistent KV/recurrent cache."""

    def serve_step(params, state, tokens):
        logits, state = T.decode_step(params, state, tokens, cfg,
                                      unroll=unroll)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, state

    return serve_step


def make_prefill_step(cfg: ArchConfig, *, unroll: bool = False):
    """prefill_step(params, batch) -> last-position logits (B, V)."""

    def prefill_step(params, batch):
        logits, _ = T.forward(params, batch, cfg, remat=False, unroll=unroll)
        return logits[:, -1]

    return prefill_step
