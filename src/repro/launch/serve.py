"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the batched engine on synthetic requests (reduced configs on CPU; the
full-config multi-pod serve_step is proven by launch/dryrun.py decode cells).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.nn import transformer as T
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    params = T.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, batch=args.batch, max_len=args.max_len)

    enc_out = None
    if cfg.encoder is not None:
        frames = jnp.zeros((args.batch, cfg.encoder.num_frames, cfg.d_model))
        enc_out = T._encoder_forward(params["encoder"], frames, cfg,
                                     remat=False)
    elif cfg.vision is not None:
        enc_out = jnp.zeros((args.batch, cfg.vision.num_patches, cfg.d_model))

    reqs = [Request(rid=i, prompt=[(7 * i + 3) % cfg.vocab_size,
                                   (11 * i + 5) % cfg.vocab_size],
                    max_new_tokens=args.max_new_tokens)
            for i in range(args.requests)]
    t0 = time.time()
    eng.run(reqs, enc_out=enc_out)
    dt = time.time() - t0
    print(json.dumps({
        "arch": cfg.name, "requests": len(reqs),
        "tokens": eng.stats.tokens_generated,
        "steps": eng.stats.steps,
        "tokens_per_s": round(eng.stats.tokens_generated / dt, 1),
        "sample_output": reqs[0].output,
    }))


if __name__ == "__main__":
    main()
