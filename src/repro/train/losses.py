"""Losses. Cross-entropy is computed against vocab-sharded logits: the
log-sum-exp reduction over the (model-axis-sharded) vocab dim lowers to a
partial reduce + all-reduce under GSPMD — no full logit gather."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits, labels, *, z_loss: float = 0.0):
    """logits (B,T,V) fp32, labels (B,T) int32 -> scalar mean NLL.
    z_loss: MaxText-style logit-norm regularizer (stabilizes bf16 training)."""
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - jax.lax.stop_gradient(m)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1, keepdims=True))
    logp = shifted - lse
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse[..., 0] + m[..., 0]))
    return loss


def next_token_loss(logits, tokens, *, aux=0.0, aux_weight: float = 0.01,
                    z_loss: float = 1e-4):
    """Shifted LM loss: predict tokens[t+1] from logits[t]."""
    loss = softmax_xent(logits[:, :-1], tokens[:, 1:], z_loss=z_loss)
    return loss + aux_weight * aux
