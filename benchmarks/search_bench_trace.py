"""Traced 2-island search -> trace JSONL: the obs acceptance path.

``python -m benchmarks.search_bench_trace [--trace PATH] [--real]`` drives
a 2-island NSGA-II search under a live `repro.obs` tracer and leaves the
trace file behind for ``python -m repro.obs.report``. The default is the
synthetic evaluator (seconds, used by CI to produce the uploaded
trace+report artifacts); ``--real`` runs the seeds printed-MLP through the
batched QAT evaluator instead, so the trace carries eval.finetune /
eval.compile_price spans with their compile-vs-steady split.
"""
from __future__ import annotations

import argparse
import tempfile
from pathlib import Path

from repro.core.ga import GAConfig
from repro.obs import trace as TR
from repro.search import IslandConfig, SearchConfig, SearchRuntime


def _synthetic(spec):
    bits = sum(l.bits for l in spec.layers)
    sp = sum(l.sparsity for l in spec.layers)
    return (bits / 16.0, sp)


def run(trace_path, *, real: bool = False, rounds: int = 4) -> Path:
    trace_path = Path(trace_path)
    with TR.capture(trace_path):
        if real:
            from repro.configs.printed_mlp import PRINTED_MLPS
            from repro.core import batch_eval as BE
            mlp = PRINTED_MLPS["seeds"]
            cfg = SearchConfig(
                n_layers=len(mlp.layer_dims) - 1, rounds=rounds,
                ga=GAConfig(population=6, seed=7,
                            input_bits=mlp.input_bits),
                islands=IslandConfig(n_islands=2, migration_every=2,
                                     migrants=1))
            with tempfile.TemporaryDirectory() as td:
                cache = BE.EvalCache(Path(td) / "evals.json")
                be = BE.make_batch_evaluator(mlp, epochs=8, seed=0,
                                             cache=cache)
                SearchRuntime(cfg, batch_evaluate=be,
                              eval_cache=cache).run()
        else:
            cfg = SearchConfig(
                n_layers=2, rounds=rounds,
                ga=GAConfig(population=8, seed=7),
                islands=IslandConfig(n_islands=2, migration_every=2,
                                     migrants=1))
            SearchRuntime(cfg, evaluate=_synthetic).run()
    return trace_path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="search_trace.jsonl")
    ap.add_argument("--real", action="store_true",
                    help="seeds printed-MLP through the batched QAT "
                         "evaluator instead of the synthetic one")
    ap.add_argument("--rounds", type=int, default=4)
    args = ap.parse_args()
    p = run(args.trace, real=args.real, rounds=args.rounds)
    records, damaged = TR.read_trace(p)
    print(f"wrote {len(records)} records to {p}"
          + (f" ({damaged} damaged)" if damaged else ""))
    print("render with: python -m repro.obs.report", p)


if __name__ == "__main__":
    main()
