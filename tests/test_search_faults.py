"""Fault-injection suite for the island-model search runtime.

The acceptance invariants of the fault-tolerance PR, asserted bit-for-bit:

* **resume equivalence** — a search preempted (killed) after ANY round and
  resumed from its checkpoint produces a byte-identical final Pareto front
  to the uninterrupted run, on real datasets with the real batched QAT
  evaluator, with ZERO evaluations re-run;
* **island kill** — a worker death mid-generation loses no completed
  evaluation and never stalls the survivors;
* **evaluation exception** — a failing spec (injected OverflowError / NaN
  accuracy) is retried once, then quarantined with worst-case fitness and
  a structured record, instead of aborting the generation;
* **torn cache file** — a truncated on-disk EvalCache is salvaged entry by
  entry (damaged bytes backed up), and the search recovers with zero
  evaluations redone.
"""
import shutil

import numpy as np
import pytest

from repro.configs.printed_mlp import PRINTED_MLPS
from repro.core import batch_eval as BE
from repro.core.compression_spec import ModelMin
from repro.core.ga import GAConfig, run_nsga2
from repro.search import (IslandConfig, PreemptedError, SearchConfig,
                          SearchRuntime)
from repro.search.faults import (EvalFault, FaultHarness, FaultPlan,
                                 inject_eval_faults)

EPOCHS = 2          # QAT epochs: enough to exercise the full real pipeline
SEED = 0
DATASETS = ("seeds", "redwine")


def _search_cfg(ds: str, rounds: int = 4) -> SearchConfig:
    cfg = PRINTED_MLPS[ds]
    return SearchConfig(
        n_layers=len(cfg.layer_dims) - 1,
        rounds=rounds,
        ga=GAConfig(population=4, seed=5, input_bits=cfg.input_bits),
        islands=IslandConfig(n_islands=2, migration_every=2, migrants=1))


def _evaluator(ds: str, cache_dir, quarantine=None):
    cache = BE.EvalCache(cache_dir / f"{ds}.json")
    return BE.make_batch_evaluator(PRINTED_MLPS[ds], epochs=EPOCHS,
                                   seed=SEED, cache=cache,
                                   quarantine=quarantine), cache


@pytest.fixture(scope="module")
def cache_dir(tmp_path_factory):
    return tmp_path_factory.mktemp("eval_caches")


@pytest.fixture(scope="module")
def baselines(cache_dir):
    """Uninterrupted searches, one per dataset — the ground truth every
    faulted/resumed run must reproduce byte-for-byte."""
    out = {}
    for ds in DATASETS:
        be, cache = _evaluator(ds, cache_dir)
        rt = SearchRuntime(_search_cfg(ds), batch_evaluate=be,
                           eval_cache=cache)
        out[ds] = rt.run()
    return out


def _assert_same_front(res, base):
    assert [s.to_json() for s in res.front_specs] == \
        [s.to_json() for s in base.front_specs]
    np.testing.assert_array_equal(res.front_objectives,
                                  base.front_objectives)
    assert res.evaluations == base.evaluations


def _count_real_evals(monkeypatch):
    """Every spec reaching `_compile_and_price` paid a real QAT finetune —
    cache hits never get there. The zero-evaluations-lost assertions count
    through this."""
    evaluated = []
    orig = BE._compile_and_price

    def counting(params_pop, specs, *a, **kw):
        evaluated.extend(s.to_json() for s in specs)
        return orig(params_pop, specs, *a, **kw)

    monkeypatch.setattr(BE, "_compile_and_price", counting)
    return evaluated


# ---------------------------------------------------------------------------
# resume equivalence (simulated preemption) — real evaluator, 2 datasets
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ds", DATASETS)
@pytest.mark.parametrize("kill_round", [0, 1, 2])
def test_preempt_resume_bit_identical(ds, kill_round, cache_dir, baselines,
                                      tmp_path, monkeypatch):
    base = baselines[ds]
    be, cache = _evaluator(ds, cache_dir)
    harness = FaultHarness(FaultPlan(preempt_at=kill_round))
    rt = SearchRuntime(_search_cfg(ds), batch_evaluate=be,
                       ckpt_root=tmp_path, harness=harness,
                       eval_cache=cache)
    with pytest.raises(PreemptedError):
        rt.run()
    assert rt.mgr.latest_step() == kill_round + 1   # preemption flushed

    # "new process": fresh evaluator + fresh cache handle over the same
    # on-disk state; count real finetunes from here on — must be zero
    # (nothing lost to the kill, nothing re-evaluated on resume)
    evaluated = _count_real_evals(monkeypatch)
    be2, cache2 = _evaluator(ds, cache_dir)
    rt2 = SearchRuntime.resume(_search_cfg(ds), tmp_path,
                               batch_evaluate=be2, eval_cache=cache2)
    res = rt2.run()
    _assert_same_front(res, base)
    assert evaluated == []


def test_resume_without_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        SearchRuntime.resume(_search_cfg("seeds"), tmp_path / "empty",
                             evaluate=lambda s: (0.5, 1.0))


# ---------------------------------------------------------------------------
# island kill — worker death mid-generation
# ---------------------------------------------------------------------------


def _synthetic(spec):
    bits = sum(l.bits for l in spec.layers)
    sp = sum(l.sparsity for l in spec.layers)
    return (bits / 16.0, sp)


def _synthetic_cfg(rounds=4, islands=3):
    return SearchConfig(
        n_layers=2, rounds=rounds,
        ga=GAConfig(population=6, seed=3),
        islands=IslandConfig(n_islands=islands, migration_every=2,
                             migrants=1))


def test_island_kill_loses_no_completed_evaluation():
    harness = FaultHarness(FaultPlan(kill_island={1: 1}))
    rt = SearchRuntime(_synthetic_cfg(), evaluate=_synthetic,
                       harness=harness)
    res = rt.run()
    gens = [st.generation for st in res.islands]
    # island 1 finished round 0, died mid-round-1 (rolled back), survivors
    # ran all 4 rounds
    assert gens == [4, 1, 4]
    kill_events = [e for e in res.events if e["event"] == "killed"]
    assert len(kill_events) == 1 and kill_events[0]["island"] == 1
    assert harness.log == [("kill", 1, 1)]
    # zero completed evaluations lost: everything the dead island ever
    # evaluated (its whole committed population) is still in the merged
    # result and counted for the front
    for spec in res.islands[1].population:
        assert spec.to_json() in res.evaluations
    # deterministic under the same fault plan
    res2 = SearchRuntime(_synthetic_cfg(), evaluate=_synthetic,
                         harness=FaultHarness(
                             FaultPlan(kill_island={1: 1}))).run()
    _assert_same_front(res2, res)


def test_all_islands_killed_raises():
    harness = FaultHarness(FaultPlan(kill_island={0: 0, 1: 0, 2: 0}))
    rt = SearchRuntime(_synthetic_cfg(), evaluate=_synthetic,
                       harness=harness)
    with pytest.raises(RuntimeError, match="every island is dead"):
        rt.run()


def test_kill_then_preempt_then_resume_keeps_dead_island_dead(tmp_path):
    plan = FaultPlan(kill_island={1: 1}, preempt_at=2)
    rt = SearchRuntime(_synthetic_cfg(), evaluate=_synthetic,
                       ckpt_root=tmp_path, harness=FaultHarness(plan))
    with pytest.raises(PreemptedError):
        rt.run()
    rt2 = SearchRuntime.resume(_synthetic_cfg(), tmp_path,
                               evaluate=_synthetic)
    res = rt2.run()
    assert [st.generation for st in res.islands] == [4, 1, 4]
    # the faulted-run ground truth: same plan, no preemption
    ref = SearchRuntime(_synthetic_cfg(), evaluate=_synthetic,
                        harness=FaultHarness(
                            FaultPlan(kill_island={1: 1}))).run()
    _assert_same_front(res, ref)


# ---------------------------------------------------------------------------
# evaluation exceptions — retry, quarantine, structured diagnostics
# ---------------------------------------------------------------------------

QSPECS = [ModelMin.uniform(2, bits=8), ModelMin.uniform(2, bits=3),
          ModelMin.uniform(2, bits=5, sparsity=0.3)]


@pytest.fixture(scope="module")
def clean_results():
    cfg = PRINTED_MLPS["seeds"]
    return BE.evaluate_population(cfg, QSPECS, epochs=EPOCHS, seed=SEED)


def test_deterministic_eval_fault_quarantined(clean_results):
    cfg = PRINTED_MLPS["seeds"]
    bad = QSPECS[1].to_json()
    q = []
    with inject_eval_faults([EvalFault(spec_json=bad, fail_attempts=2)]):
        rs = BE.evaluate_population(cfg, QSPECS, epochs=EPOCHS, seed=SEED,
                                    quarantine=q)
    # the failing spec got worst-case fitness, not a crashed generation
    assert rs[1].accuracy == 0.0
    assert rs[1].area_mm2 == BE.QUARANTINE_AREA_MM2
    assert rs[1].delay_levels == BE.QUARANTINE_DELAY_LEVELS
    # structured diagnostics
    assert len(q) == 1
    rec = q[0]
    assert rec.spec_json == bad
    assert rec.error == "OverflowError"
    assert rec.attempts == 2
    assert "netlist sim budget" in rec.message
    # bystanders are untouched — byte-identical to the clean run
    for i in (0, 2):
        assert rs[i] == clean_results[i]


def test_transient_eval_fault_absorbed_by_retry(clean_results):
    cfg = PRINTED_MLPS["seeds"]
    bad = QSPECS[0].to_json()
    q = []
    with inject_eval_faults([EvalFault(spec_json=bad,
                                       fail_attempts=1)]) as hook:
        rs = BE.evaluate_population(cfg, QSPECS, epochs=EPOCHS, seed=SEED,
                                    quarantine=q)
    assert hook.triggered == [(bad, 1)]     # the fault really fired
    assert q == []                          # ...and the retry absorbed it
    assert rs[0] == clean_results[0]


def test_nan_accuracy_quarantined(monkeypatch):
    from repro.core import minimize as MZ
    cfg = PRINTED_MLPS["seeds"]
    monkeypatch.setattr(MZ, "compiled_accuracy",
                        lambda c, x, y: float("nan"))
    q = []
    # the NaN guard belongs to the analytic scorer (an integer-argmax
    # netlist accuracy cannot come back NaN), so opt out of the default
    rs = BE.evaluate_population(cfg, [QSPECS[0]], epochs=EPOCHS, seed=SEED,
                                quarantine=q, netlist=False)
    assert rs[0].accuracy == 0.0
    assert len(q) == 1
    assert q[0].stage == "score"
    assert q[0].error == "FloatingPointError"
    assert "NaN accuracy" in q[0].message


def test_quarantined_specs_never_cached(tmp_path):
    cfg = PRINTED_MLPS["seeds"]
    cache = BE.EvalCache(tmp_path / "c.json")
    bad = QSPECS[1].to_json()
    with inject_eval_faults([EvalFault(spec_json=bad, fail_attempts=2)]):
        BE.evaluate_population(cfg, QSPECS, epochs=EPOCHS, seed=SEED,
                               cache=cache, quarantine=[])
    # healthy specs cached (under the default netlist-exact keyspace),
    # the quarantined one left for a fixed toolchain
    assert cache.get(cfg.name, SEED, EPOCHS, QSPECS[0],
                     netlist=True) is not None
    assert cache.get(cfg.name, SEED, EPOCHS, QSPECS[1], netlist=True) is None


def test_quarantine_surfaces_in_ga_result():
    """A GA search whose every evaluation fails still completes, with the
    records surfaced on GAResult.quarantined."""
    cfg = PRINTED_MLPS["seeds"]
    q = []
    be = BE.make_batch_evaluator(cfg, epochs=EPOCHS, seed=SEED,
                                 quarantine=q)
    with inject_eval_faults([EvalFault(fail_attempts=2)]):   # every spec
        res = run_nsga2(2, None,
                        GAConfig(population=4, generations=1, seed=0),
                        batch_evaluate=be, quarantine=q)
    assert len(res.quarantined) > 0
    assert all(r.attempts == 2 for r in res.quarantined)
    # worst-case fitness everywhere: acc objective 1.0, area penalty
    assert np.all(res.objectives[:, 0] == 1.0)
    assert np.all(res.objectives[:, 1] == BE.QUARANTINE_AREA_MM2)


# ---------------------------------------------------------------------------
# torn cache file
# ---------------------------------------------------------------------------


def test_torn_cache_salvaged_and_search_recovers(cache_dir, baselines,
                                                 tmp_path, monkeypatch):
    """Truncate the on-disk EvalCache mid-search: the next flush salvages
    the readable entries, backs the damaged bytes up to `.corrupt`, and
    the search finishes with a bit-identical front and zero re-runs."""
    ds = "seeds"
    # clean 3-round ground truth (pure cache replay of the baseline run)
    be, cache = _evaluator(ds, cache_dir)
    ref = SearchRuntime(_search_cfg(ds, rounds=3), batch_evaluate=be,
                        eval_cache=cache).run()

    # private copy of the warm cache that the harness will tear
    torn_path = tmp_path / "torn.json"
    shutil.copy(cache_dir / f"{ds}.json", torn_path)
    evaluated = _count_real_evals(monkeypatch)
    # a fully-warm replay batches its recency-only flushes; force them
    # eager so the first flush after the tear re-reads (and salvages) disk
    monkeypatch.setattr(BE.EvalCache, "TOUCH_FLUSH_EVERY", 1)
    cache2 = BE.EvalCache(torn_path)
    be2 = BE.make_batch_evaluator(PRINTED_MLPS[ds], epochs=EPOCHS,
                                  seed=SEED, cache=cache2)
    harness = FaultHarness(FaultPlan(tear_cache_at=2),
                           cache_path=torn_path)
    rt = SearchRuntime(_search_cfg(ds, rounds=3), batch_evaluate=be2,
                       harness=harness, eval_cache=cache2)
    res = rt.run()
    assert any(ev[0] == "tear_cache" for ev in harness.log)
    _assert_same_front(res, ref)
    assert evaluated == []                    # zero evaluations redone
    # the damaged bytes were preserved for post-mortem...
    assert torn_path.with_suffix(".json.corrupt").exists()
    # ...and the rewritten cache is whole again: a fresh reader sees every
    # entry the in-memory cache knew
    assert len(BE.EvalCache(torn_path)) == len(cache2)
