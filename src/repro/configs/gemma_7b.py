"""gemma-7b [dense] — GeGLU, head_dim=256. [arXiv:2403.08295]"""
from repro.configs.base import ArchConfig, LayerSpec, Segment

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    d_model=3072,
    vocab_size=256000,
    segments=(Segment((LayerSpec("attn", "dense"),), 28),),
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    mlp_type="geglu",
    norm_unit_offset=True,
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10000.0,
    source="arXiv:2403.08295; hf",
)
