"""Approximation bench: budgeted pass sweeps + the approximation-aware GA.

Part A — **budget sweep**: for each printed-MLP dataset, the minimized
design point (4-bit / 0.4-sparsity / 8-cluster) is lowered to its bespoke
netlist and greedily approximated (`approx.fit_budget`) under three
worst-case logit-error budgets (fractions of the logit range). Per row:
the knobs chosen, the analyzer's PROVEN error bound vs the measured max
logit error on the full test set (soundness — asserted: measured <=
bound), area before/after, and netlist-exact accuracy before/after.

Part B — **GA with approximation genes**: the combined hardware-aware
search on one dataset, once with the paper's exact genome and once with
the circuit-approximation genes enabled. Acceptance (asserted): the
approximating run reaches a Pareto point with LOWER area than the best
exact point at <= 5% accuracy drop from the dense 8-bit baseline — the
next multiplier beyond minimization (Armeniakos DATE'22).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro import approx, circuit
from repro.configs.printed_mlp import PRINTED_MLPS
from repro.core import batch_eval as BE
from repro.core import minimize as MZ
from repro.core.compression_spec import ModelMin

BUDGET_FRACS = (0.002, 0.01, 0.05)


def budget_sweep(datasets: Optional[List[str]] = None, *,
                 epochs: int = 60, seed: int = 0) -> List[Dict]:
    rows = []
    for name in (datasets or sorted(PRINTED_MLPS)):
        cfg = PRINTED_MLPS[name]
        n_layers = len(cfg.layer_dims) - 1
        spec = ModelMin.uniform(n_layers, bits=4, sparsity=0.4, clusters=8,
                                input_bits=cfg.input_bits)
        # the full minimization recipe (QAT finetune under the spec), same
        # as evaluate_spec / the batched engine — the rows really are the
        # minimized design point
        params0, (xtr, ytr, xte, yte) = MZ.pretrain(cfg, seed=seed)
        masks = MZ.make_masks(params0, spec)
        params = MZ.qat_finetune(params0, spec, masks, xtr, ytr,
                                 epochs=epochs)
        compiled = MZ.compile_bespoke(params, spec, masks)
        net = circuit.compile_netlist(compiled)
        sc = circuit.structural_cost(net)
        acc0 = circuit.netlist_accuracy(net, compiled, xte, yte)
        for frac in BUDGET_FRACS:
            budget = approx.logit_budget(net, frac)
            t0 = time.perf_counter()
            params, anet, rep = approx.fit_budget(net, budget)
            fit_ms = (time.perf_counter() - t0) * 1e3
            measured = approx.measured_max_logit_error(anet, compiled, xte)
            acc = circuit.netlist_accuracy(anet, compiled, xte, yte)
            asc = circuit.structural_cost(anet)
            rows.append({
                "dataset": name, "budget_frac": frac, "budget": budget,
                "bound": rep.bound, "measured": measured,
                "sound": measured <= rep.logit_bound,
                "exact_area_mm2": sc.area_mm2,
                "approx_area_mm2": asc.area_mm2,
                "area_gain": sc.area_mm2 / max(asc.area_mm2, 1e-9),
                "exact_acc": acc0, "approx_acc": acc,
                "csd_drop": params.csd_drop, "lsb": params.lsb,
                "argmax_lsb": params.argmax_lsb, "fit_ms": fit_ms,
            })
    return rows


def ga_compare(dataset: str = "seeds", *, population: int = 10,
               generations: int = 4, epochs: int = 40,
               seed: int = 0) -> Dict:
    """Exact-genome GA vs approximation-genome GA on one dataset. Both use
    the netlist-exact accuracy objective so the comparison is apples to
    apples on the simulated printed datapath."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks import fig2_combined

    cfg = PRINTED_MLPS[dataset]
    base = MZ.baseline(cfg)
    floor = base.accuracy - 0.05

    out = {}
    for tag, ax in (("exact", False), ("approx", True)):
        res = fig2_combined.run(dataset, population=population,
                                generations=generations, epochs=epochs,
                                seed=seed, netlist=True, approx=ax)
        # best (min-area) evaluated point within the 5%-loss envelope,
        # split by whether the candidate carries approximation genes
        best: Dict[str, Optional[float]] = {"exact": None, "approx": None}
        for spec_json, objs in res["evaluations"].items():
            acc, area = 1.0 - objs[0], objs[1]
            kind = ("approx" if ModelMin.from_json(spec_json).has_approx
                    else "exact")
            if acc >= floor and (best[kind] is None or area < best[kind]):
                best[kind] = area
        out[tag] = {"front": res["pareto_front"],
                    "n_evaluations": res["n_evaluations"],
                    "best_exact_area": best["exact"],
                    "best_approx_area": best["approx"]}

    exact_best = out["exact"]["best_exact_area"]
    # the approximating run sees exact candidates too — its exact best can
    # only improve on the exact run's; compare its approx best to the
    # tightest exact area either run found
    cands = [out[t]["best_exact_area"] for t in out
             if out[t]["best_exact_area"] is not None]
    tightest_exact = min(cands) if cands else exact_best
    return {
        "dataset": dataset, "baseline_acc": base.accuracy, "floor": floor,
        "best_exact_area": tightest_exact,
        "best_approx_area": out["approx"]["best_approx_area"],
        "runs": out,
    }


def main(fast: bool = False):
    t0 = time.time()
    rows = budget_sweep(["seeds", "whitewine"] if fast else None)
    print("approx_bench A: greedy budgeted approximation "
          "(proven worst-case logit-error bounds)")
    print("dataset,budget_frac,bound,measured,sound,area_exact,area_approx,"
          "gain,acc_exact,acc_approx,knobs")
    ok = True
    for r in rows:
        knobs = (f"csd{list(r['csd_drop'])}/lsb{list(r['lsb'])}"
                 f"/am{r['argmax_lsb']}")
        print(f"{r['dataset']},{r['budget_frac']},{r['bound']},"
              f"{r['measured']},{r['sound']},{r['exact_area_mm2']:.0f},"
              f"{r['approx_area_mm2']:.0f},{r['area_gain']:.2f},"
              f"{r['exact_acc']:.3f},{r['approx_acc']:.3f},{knobs}")
        ok &= r["sound"]
    assert ok, "measured logit error exceeded the analyzer's bound"

    ga = ga_compare(population=8 if fast else 10,
                    generations=3 if fast else 4,
                    epochs=30 if fast else 40)
    print(f"\napprox_bench B: GA with approximation genes "
          f"({ga['dataset']}, acc floor {ga['floor']:.3f})")
    be, ba = ga["best_exact_area"], ga["best_approx_area"]
    print(f"best exact-point area   : "
          f"{'-' if be is None else f'{be:.1f} mm2'}")
    print(f"best approx-point area  : "
          f"{'-' if ba is None else f'{ba:.1f} mm2'}")
    wins = ba is not None and be is not None and ba < be
    print(f"acceptance (approx Pareto point beats best exact at <=5% "
          f"loss): {'PASS' if wins else 'FAIL'}")
    assert wins, "approximation genes failed to beat the exact frontier"
    print(f"[{time.time()-t0:.0f}s]")
    return {"budget_sweep": rows, "ga": ga}


if __name__ == "__main__":
    main()
