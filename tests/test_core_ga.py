"""Tests for the NSGA-II search + pareto utilities."""
import numpy as np

from repro.core import pareto as PR
from repro.core.compression_spec import LayerMin, ModelMin
from repro.core.ga import GAConfig, run_nsga2


def test_non_dominated_sort_simple():
    pts = np.array([[0.0, 1.0], [1.0, 0.0], [1.0, 1.0], [2.0, 2.0]])
    fronts = PR.non_dominated_sort(pts)
    assert set(fronts[0].tolist()) == {0, 1}
    assert set(fronts[1].tolist()) == {2}
    assert set(fronts[2].tolist()) == {3}


def test_pareto_front_invariant():
    rng = np.random.default_rng(0)
    pts = rng.random((50, 2))
    front = PR.pareto_front(pts)
    for i in front:
        for j in range(len(pts)):
            assert not PR.dominates(pts[j], pts[i])


def test_hypervolume_monotone():
    a = np.array([[0.5, 0.5]])
    b = np.array([[0.5, 0.5], [0.2, 0.8]])
    assert PR.hypervolume_2d(b, (1, 1)) >= PR.hypervolume_2d(a, (1, 1))


def test_gain_at_loss():
    pts = [(0.90, 100.0), (0.87, 20.0), (0.80, 5.0)]
    g = PR.gain_at_loss(pts, baseline_acc=0.90, baseline_area=100.0,
                        max_loss=0.05)
    assert abs(g - 5.0) < 1e-9     # the 0.87/20 point qualifies, 0.80 doesn't


def test_nsga2_converges_on_synthetic_objective():
    """Objective: cost = bits + 10*(1-sparsity); acc proxy penalizes extremes.
    The GA should find cheaper configs than random init."""
    def evaluate(spec: ModelMin):
        bits = np.mean([l.bits for l in spec.layers])
        sp = np.mean([l.sparsity for l in spec.layers])
        acc = 1.0 - 0.02 * max(0, 5 - bits) ** 2 - 0.3 * sp ** 2
        cost = bits * 10 + (1 - sp) * 20
        return (1.0 - acc, cost)

    res = run_nsga2(2, evaluate, GAConfig(population=12, generations=6, seed=1))
    assert len(res.population) == 12
    # best accuracy on the front should be near 1.0 and min cost well below max
    assert res.objectives[:, 0].min() < 0.05
    assert res.objectives[:, 1].min() < 60
    # history recorded per generation
    assert len(res.history) == 6
    # pareto: no population member dominates another on the first front
    front = PR.pareto_front(res.objectives)
    assert len(front) >= 1


def test_nsga2_deterministic():
    def evaluate(spec):
        return (sum(l.bits for l in spec.layers) / 16.0,
                sum(l.sparsity for l in spec.layers))
    r1 = run_nsga2(2, evaluate, GAConfig(population=8, generations=3, seed=7))
    r2 = run_nsga2(2, evaluate, GAConfig(population=8, generations=3, seed=7))
    assert [s.to_json() for s in r1.population] == \
        [s.to_json() for s in r2.population]


def test_nsga2_handles_three_objectives():
    """NSGA-II is dimension-agnostic: a netlist-exact evaluator can add
    critical-path delay as a third minimized objective, and the history
    tracks its per-generation minimum."""
    def evaluate(spec):
        bits = sum(l.bits for l in spec.layers)
        sp = sum(l.sparsity for l in spec.layers)
        return (bits / 16.0, sp, float(10 + bits))   # delay grows with bits
    res = run_nsga2(2, evaluate, GAConfig(population=8, generations=3,
                                          seed=2))
    assert res.objectives.shape == (8, 3)
    assert all("min_delay" in h for h in res.history)
    assert res.history[-1]["min_delay"] >= 10.0


def test_spec_json_roundtrip():
    spec = ModelMin((LayerMin(4, 0.3, 8), LayerMin(None, 0.0, None)), 8)
    assert ModelMin.from_json(spec.to_json()) == spec


def test_nsga2_propagates_input_bits():
    """Regression: random genomes seeded into the population must carry the
    search's input_bits (seed specs win, else GAConfig), not the ModelMin
    default of 8."""
    def evaluate(spec):
        return (0.5, float(sum(l.bits for l in spec.layers)))

    # from seed specs
    seeds = [ModelMin.uniform(2, bits=4, input_bits=6)]
    res = run_nsga2(2, evaluate,
                    GAConfig(population=6, generations=2, seed=3),
                    seed_specs=seeds)
    assert all(s.input_bits == 6 for s in res.population)
    # from config when there are no seed specs
    res2 = run_nsga2(2, evaluate,
                     GAConfig(population=6, generations=2, seed=3,
                              input_bits=5))
    assert all(s.input_bits == 5 for s in res2.population)


def test_nsga2_batch_evaluate_matches_serial_path():
    """batch_evaluate is a pure performance hook: identical GA trajectory."""
    def evaluate(spec):
        return (sum(l.bits for l in spec.layers) / 16.0,
                sum(l.sparsity for l in spec.layers))

    calls = []

    def batch_evaluate(specs):
        calls.append(len(specs))
        return [evaluate(s) for s in specs]

    cfg = GAConfig(population=8, generations=3, seed=7)
    r1 = run_nsga2(2, evaluate, cfg)
    r2 = run_nsga2(2, None, cfg, batch_evaluate=batch_evaluate)
    assert [s.to_json() for s in r1.population] == \
        [s.to_json() for s in r2.population]
    np.testing.assert_array_equal(r1.objectives, r2.objectives)
    # every generation fitted in batch calls, never one-by-one
    assert sum(calls) == len(r2.evaluations)
