"""Paper §III evaluation-setup table: baseline bespoke MLP circuits
(topology, multipliers, simulated area/power, accuracy) for the four UCI
classifiers — the quantities [1]'s table provides and against which Fig. 1/2
normalize."""
from __future__ import annotations

import time

from repro.configs.printed_mlp import PRINTED_MLPS
from repro.core import minimize as MZ


def run():
    out = {}
    for name, cfg in PRINTED_MLPS.items():
        b = MZ.baseline(cfg)
        out[name] = {
            "topology": "-".join(map(str, cfg.layer_dims)),
            "accuracy": round(b.accuracy, 4),
            "area_cm2": round(b.area_mm2 / 100, 2),
            "power_mw": round(b.power_mw, 1),
            "multipliers": b.n_multipliers,
        }
    return out


def main(fast: bool = False):
    t0 = time.time()
    res = run()
    print("area_table (un-minimized 8-bit bespoke baselines, simulated EGT)")
    print(f"{'dataset':12s} {'topology':>10s} {'acc':>6s} {'cm2':>8s} "
          f"{'mW':>8s} {'mults':>6s}")
    for name, r in res.items():
        print(f"{name:12s} {r['topology']:>10s} {r['accuracy']:6.3f} "
              f"{r['area_cm2']:8.2f} {r['power_mw']:8.1f} "
              f"{r['multipliers']:6d}")
    print(f"[{time.time()-t0:.0f}s]")
    return res


if __name__ == "__main__":
    main()
