"""Netlist IR for bespoke printed-MLP circuits.

A :class:`Netlist` is a flat, topologically-ordered list of typed integer
nodes — the dataflow graph of one bespoke classifier as it would be printed:
hardwired constants, ADC inputs, the shift-add networks of every
constant-coefficient multiplier, per-neuron adder trees, bias adds, ReLU
comparators and the final argmax comparator tree.

Every node carries an exact value interval ``[lo, hi]`` propagated from the
inputs (interval arithmetic over the integer ops), from which its minimal
two's-complement ``width`` follows — widths are *derived*, never guessed, so
the simulator can pick a machine dtype that provably cannot overflow and the
cost model can report true per-node wordlengths.

Ops
---
``CONST``   hardwired integer (weights/biases are baked into the layout)
``INPUT``   ADC lane, unsigned ``in_bits`` fixed point
``SHL``     wire shift by a static amount (free: routing, no gates)
``ADD/SUB`` ripple adder/subtractor
``NEG``     two's-complement negate (inverter row + carry-in)
``RELU``    comparator + mux against zero
``ARGMAX``  comparator tree over the class logits -> class index
``TRUNC``   drop the ``shift`` low bits: (a >> k) << k. Free wiring (the low
            wires are simply not connected); downstream adders narrow by k.
            Only the approximation passes (`repro.approx`) emit it.

Approximation bookkeeping: a node may carry a *local* error interval
``[err_lo, err_hi]`` — the worst-case deviation a rewrite pass introduced AT
this node relative to the exact reference circuit (e.g. a rounded
multiplier coefficient). `repro.approx.analyze` propagates these local
intervals (plus TRUNC's intrinsic ``[-(2^k - 1), 0]``) through the graph
into per-logit worst-case bounds. Exact netlists carry ``(0, 0)``
everywhere.

Roles tag each node with its microarchitectural home (``mult`` — inside a
constant multiplier, ``tree`` — adder tree, ``bias`` — bias add, ``relu``,
``argmax``), plus the layer index and the unit (neuron / (row, cluster))
that owns it. ``circuit.cost`` prices the netlist purely from these tags
and the graph structure; ``circuit.simulate`` ignores them.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, List, Optional, Sequence, Tuple


class Op(enum.IntEnum):
    CONST = 0
    INPUT = 1
    SHL = 2
    ADD = 3
    SUB = 4
    NEG = 5
    RELU = 6
    ARGMAX = 7
    TRUNC = 8


# roles a node can play in the bespoke microarchitecture
ROLE_CONST = "const"
ROLE_INPUT = "input"
ROLE_MULT = "mult"       # inside a constant-coefficient multiplier subnet
ROLE_TREE = "tree"       # per-neuron adder tree
ROLE_BIAS = "bias"       # per-neuron bias add (accumulator register add)
ROLE_RELU = "relu"
ROLE_ARGMAX = "argmax"


def _twos_complement_bits(lo: int, hi: int) -> int:
    """Minimal two's-complement width holding every integer in [lo, hi]."""
    assert lo <= hi, (lo, hi)
    bits_hi = hi.bit_length() + 1 if hi > 0 else 1       # sign bit included
    bits_lo = (-lo - 1).bit_length() + 1 if lo < 0 else 1
    return max(bits_hi, bits_lo)


@dataclasses.dataclass
class Node:
    """One typed integer node. ``args`` reference earlier node ids only
    (the netlist is constructed in topological order and validated)."""
    id: int
    op: Op
    args: Tuple[int, ...] = ()
    value: int = 0                    # CONST payload
    shift: int = 0                    # SHL amount (static)
    lo: int = 0                       # exact value interval
    hi: int = 0
    role: str = ROLE_CONST
    layer: int = -1                   # owning layer (-1: input / argmax)
    unit: Tuple[int, ...] = ()        # neuron k, or (row j, cluster m)
    product_root: bool = False        # root of one bespoke multiplier subnet
    err_lo: int = 0                   # local approximation error introduced
    err_hi: int = 0                   # at this node (0/0 for exact nodes)

    @property
    def width(self) -> int:
        return _twos_complement_bits(self.lo, self.hi)


class Netlist:
    """Topologically-ordered node list + the classifier-level bookkeeping
    the compiler records: per-layer pre-activation nodes (the bias-add
    outputs — the *integer pre-activations* the QAT reference path defines),
    the logit nodes and the argmax node.

    ``in_bits`` / ``w_bits`` mirror the analytic model's width conventions
    so ``circuit.cost`` can cross-validate ``hw_model`` exactly.
    """

    def __init__(self, *, in_bits: int, w_bits: Sequence[int]):
        self.nodes: List[Node] = []
        self.in_bits = int(in_bits)
        self.w_bits = [int(b) for b in w_bits]
        self.input_ids: List[int] = []
        self.layer_pre_ids: List[List[int]] = []   # bias-add node per neuron
        self.output_ids: List[int] = []            # final-layer logits
        self.argmax_id: Optional[int] = None
        self._const_cache: Dict[int, int] = {}     # value -> node id

    # -- construction -------------------------------------------------------

    def _add(self, node: Node) -> int:
        for a in node.args:
            assert 0 <= a < node.id, (node.id, node.args)
        self.nodes.append(node)
        return node.id

    def const(self, value: int, *, layer: int = -1,
              role: str = ROLE_CONST, unit: Tuple[int, ...] = ()) -> int:
        """Hardwired integer. Deduplicated by value: a printed constant is
        a wire pattern, re-usable everywhere. Because the node is shared,
        caller tags are NOT honored — every CONST carries the canonical
        tags (role=const, layer=-1, unit=()); honoring them would let a
        value-cache hit silently return a node tagged by the *first*
        caller (the verifier enforces canonicality)."""
        del layer, role, unit              # shared node: tags are canonical
        value = int(value)
        if value in self._const_cache:
            return self._const_cache[value]
        nid = self._add(Node(len(self.nodes), Op.CONST, value=value,
                             lo=value, hi=value))
        self._const_cache[value] = nid
        return nid

    def input(self, lane: int) -> int:
        hi = (1 << self.in_bits) - 1
        nid = self._add(Node(len(self.nodes), Op.INPUT, lo=0, hi=hi,
                             role=ROLE_INPUT, unit=(lane,)))
        self.input_ids.append(nid)
        return nid

    def shl(self, a: int, shift: int, **tags) -> int:
        n = self.nodes[a]
        return self._add(Node(len(self.nodes), Op.SHL, (a,), shift=int(shift),
                              lo=n.lo << shift, hi=n.hi << shift, **tags))

    def add(self, a: int, b: int, **tags) -> int:
        na, nb = self.nodes[a], self.nodes[b]
        return self._add(Node(len(self.nodes), Op.ADD, (a, b),
                              lo=na.lo + nb.lo, hi=na.hi + nb.hi, **tags))

    def sub(self, a: int, b: int, **tags) -> int:
        na, nb = self.nodes[a], self.nodes[b]
        return self._add(Node(len(self.nodes), Op.SUB, (a, b),
                              lo=na.lo - nb.hi, hi=na.hi - nb.lo, **tags))

    def neg(self, a: int, **tags) -> int:
        n = self.nodes[a]
        return self._add(Node(len(self.nodes), Op.NEG, (a,),
                              lo=-n.hi, hi=-n.lo, **tags))

    def trunc(self, a: int, shift: int, **tags) -> int:
        """Drop the ``shift`` low bits of ``a``: (a >> shift) << shift with
        arithmetic (floor) semantics. shift == 0 is the identity and emits
        no node. Free wiring — the approximation passes use it to narrow
        downstream adders/comparators."""
        if shift <= 0:
            return a
        n = self.nodes[a]
        return self._add(Node(len(self.nodes), Op.TRUNC, (a,),
                              shift=int(shift),
                              lo=(n.lo >> shift) << shift,
                              hi=(n.hi >> shift) << shift, **tags))

    def relu(self, a: int, **tags) -> int:
        n = self.nodes[a]
        return self._add(Node(len(self.nodes), Op.RELU, (a,),
                              lo=max(n.lo, 0), hi=max(n.hi, 0), **tags))

    def argmax(self, logits: Sequence[int]) -> int:
        logits = tuple(logits)
        if not logits:
            raise ValueError("argmax over an empty logit list")
        if self.argmax_id is not None:
            raise ValueError(
                "argmax already lowered (one comparator tree per netlist)")
        nid = self._add(Node(len(self.nodes), Op.ARGMAX, logits,
                             lo=0, hi=len(logits) - 1, role=ROLE_ARGMAX))
        self.argmax_id = nid
        return nid

    # -- analysis -----------------------------------------------------------

    def __len__(self) -> int:
        return len(self.nodes)

    @property
    def n_layers(self) -> int:
        return len(self.layer_pre_ids)

    @property
    def max_width(self) -> int:
        return max(n.width for n in self.nodes)

    def depths(self) -> List[int]:
        """Adder-stage depth per node: SHL/TRUNC/CONST/INPUT are wires (+0);
        ADD/SUB/NEG/RELU are one gate stage (+1); ARGMAX is a comparator
        tree, ceil(log2(#logits)) stages. The max over the netlist is the
        critical-path length in full-adder-stage delays."""
        depth = [0] * len(self.nodes)
        for n in self.nodes:
            d = max((depth[a] for a in n.args), default=0)
            if n.op in (Op.ADD, Op.SUB, Op.NEG, Op.RELU):
                d += 1
            elif n.op == Op.ARGMAX:
                d += max(math.ceil(math.log2(max(len(n.args), 2))), 1)
            depth[n.id] = d
        return depth

    def critical_path_levels(self) -> int:
        if not self.nodes:
            return 0
        return max(self.depths())

    def levels(self) -> List[List[int]]:
        """Topological level per node (all args strictly earlier levels) —
        the simulator's batching unit. CONST/INPUT sit at level 0. An
        empty netlist has no levels."""
        if not self.nodes:
            return []
        lev = [0] * len(self.nodes)
        out: List[List[int]] = [[]]
        for n in self.nodes:
            l = 1 + max((lev[a] for a in n.args), default=-1) \
                if n.args else 0
            lev[n.id] = l
            while len(out) <= l:
                out.append([])
            out[l].append(n.id)
        return out

    def op_counts(self) -> Dict[str, int]:
        c: Dict[str, int] = {}
        for n in self.nodes:
            c[n.op.name] = c.get(n.op.name, 0) + 1
        return c

    def validate(self) -> None:
        """Structural invariants — delegates to the independent verifier
        (`repro.verify.netlist`): topo order + opcode arity, re-derived
        value intervals, level/depth consistency, CONST dedup, classifier
        bookkeeping, argmax terminality, and the 62-bit simulation budget
        (still raised as the historical OverflowError). Raises
        `repro.verify.VerificationError` (an AssertionError) with the
        full diagnostic list otherwise. Microarchitectural conventions
        (role legality, TRUNC provenance) are reported but non-fatal
        here; the compiler and the pass pipeline check their own outputs
        in strict mode."""
        from repro.verify.netlist import check_netlist
        check_netlist(self)
