"""Benchmark harness: one function per paper table/figure + framework
benches. Prints ``name,us_per_call,derived`` CSV summaries at the end.

  fig1_standalone — paper Fig. 1 (standalone technique Pareto fronts)
  fig2_combined   — paper Fig. 2 (hardware-aware GA, combined techniques)
  area_table      — paper §III baseline circuit table
  kernel_bench    — per-kernel derived TPU roofline
  roofline_table  — §Roofline across all dry-run cells
  ga_bench        — GA hot path: serial vs batched population evaluation
  circuit_bench   — bespoke netlist compile / bit-exact sim / delay
  netlist_bench   — netlist-exact vs analytic GA generation (<=2x gate)
  approx_bench    — budgeted circuit approximation + approximation-GA
  search_bench    — island runtime: throughput / checkpoint / resume cost

``python -m benchmarks.run [--fast] [--only NAME] [--compare BASELINE]``

``--compare`` reads a previously-saved ``name,us_per_call,...`` CSV (e.g.
the committed ``benchmarks/baseline.csv``) and warns on every bench whose
wall-clock regressed more than 15% against it — names missing on either
side are skipped, so partial runs (``--only``) compare cleanly.

Every bench row also carries executable-observatory profile columns
(`repro.obs.xprof` / `repro.obs.metrics`, no tracing required):

  compiles      — XLA backend compiles during the bench (count_compiles)
  compile_s     — wall-clock spent inside the backend compiler
  pad_waste_pct — % of packed slots (netlist lanes + eval bucket specs)
                  burned on NOP/replica padding, from the always-on
                  padding counters' before/after deltas

``--compare`` warns on these too: a bench whose compile count grew >25%
(and by at least 5 compiles) over baseline is flagged as a recompile
regression even when the wall-clock still squeaks under the 15% gate —
compile churn hides inside timing noise long before it dominates it.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path
from typing import Dict

from repro.obs import metrics as MT
from repro.obs import xprof

from benchmarks import approx_bench, area_table, circuit_bench, \
    dryrun_memory_table, fig1_standalone, fig2_combined, ga_bench, \
    kernel_bench, netlist_bench, roofline_table, search_bench

BENCHES = [
    ("area_table", area_table.main),
    ("fig1_standalone", fig1_standalone.main),
    ("fig2_combined", fig2_combined.main),
    ("kernel_bench", kernel_bench.main),
    ("roofline_table", roofline_table.main),
    ("dryrun_memory_table", dryrun_memory_table.main),
    ("ga_bench", ga_bench.main),
    ("circuit_bench", circuit_bench.main),
    ("netlist_bench", netlist_bench.main),
    ("approx_bench", approx_bench.main),
    ("search_bench", search_bench.main),
]


_PAD_COUNTERS = (  # (real, total) always-on padding accounts, in "slots"
    ("netlist_sim.pad.lanes_used", "netlist_sim.pad.lanes_total"),
    ("eval.pad.specs_real", "eval.pad.specs_total"),
)


def _pad_totals() -> Dict[str, int]:
    return {n: MT.counter(n).value for pair in _PAD_COUNTERS for n in pair}


def _pad_waste_pct(before: Dict[str, int], after: Dict[str, int]) -> float:
    """% of packed slots that were padding during the window, over the
    netlist lane and eval bucket accounts combined. 0 when nothing packed."""
    real = total = 0
    for r, t in _PAD_COUNTERS:
        real += after[r] - before[r]
        total += after[t] - before[t]
    return 100.0 * (1.0 - real / total) if total > 0 else 0.0


def load_baseline(path) -> Dict[str, Dict[str, float]]:
    """``name,us_per_call[,compiles,...]`` CSV -> {name: {us, compiles}}.
    Header lines and unparsable rows are skipped; profile columns are
    optional so pre-observatory baselines still compare on wall-clock."""
    out: Dict[str, Dict[str, float]] = {}
    for line in Path(path).read_text().splitlines():
        parts = line.strip().split(",")
        if len(parts) < 2 or parts[0] == "name":
            continue
        try:
            row = {"us": float(parts[1])}
        except ValueError:
            continue
        if len(parts) >= 3:
            try:
                row["compiles"] = float(parts[2])
            except ValueError:
                pass
        out[parts[0]] = row
    return out


def compare_against(baseline: Dict[str, Dict[str, float]],
                    current: Dict[str, Dict[str, float]],
                    threshold: float = 0.15,
                    compile_threshold: float = 0.25,
                    compile_floor: int = 5) -> Dict[str, str]:
    """{name: warning text} for regressed benches: wall-clock slower than
    baseline by > ``threshold``, or backend-compile count grown by more
    than ``compile_threshold`` AND at least ``compile_floor`` compiles."""
    out: Dict[str, str] = {}
    for name, cur in current.items():
        base = baseline.get(name)
        if base is None:
            continue
        if base["us"] > 0 and cur["us"] > base["us"] * (1.0 + threshold):
            out[name] = (f"regressed {cur['us'] / base['us'] * 100 - 100:.0f}%"
                         f" wall-clock (>{threshold * 100:.0f}% threshold)")
        elif ("compiles" in base and cur["compiles"] >
                max(base["compiles"] * (1.0 + compile_threshold),
                    base["compiles"] + compile_floor)):
            out[name] = (f"compiled {cur['compiles']:.0f} executables vs "
                         f"{base['compiles']:.0f} at baseline (recompile "
                         "regression: a static-shape key is churning)")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--compare", default=None, metavar="BASELINE_CSV",
                    help="warn on benches >15%% slower than this "
                         "name,us_per_call CSV")
    args = ap.parse_args()

    csv = []
    current: Dict[str, Dict[str, float]] = {}
    for name, fn in BENCHES:
        if args.only and name != args.only:
            continue
        print(f"\n=== {name} {'=' * (60 - len(name))}")
        pad0 = _pad_totals()
        t0 = time.time()
        with xprof.count_compiles() as cc:
            fn(fast=args.fast)
        us = (time.time() - t0) * 1e6
        waste = _pad_waste_pct(pad0, _pad_totals())
        current[name] = {"us": us, "compiles": float(cc.compiles)}
        csv.append(f"{name},{us:.0f},{cc.compiles},{cc.compile_s:.2f},"
                   f"{waste:.1f},see-above")
    print("\nname,us_per_call,compiles,compile_s,pad_waste_pct,derived")
    for line in csv:
        print(line)

    if args.compare:
        regressions = compare_against(load_baseline(args.compare), current)
        for name, why in sorted(regressions.items()):
            print(f"WARNING: {name} {why} vs {args.compare}")
        if not regressions:
            print(f"compare: no regressions vs {args.compare}")


if __name__ == "__main__":
    main()
