"""Netlist verifier: machine-checked invariants of the circuit IR.

Everything the compiler (`circuit.compile`), the rebuild machinery
(`approx.rewrite`) and the cost/simulation consumers rely on is re-derived
here *independently* — the verifier never calls the methods it is checking
(`levels()`, `depths()`, the builder interval rules), it re-implements
their documented semantics and compares. A bug in either side surfaces as
a diagnostic instead of a silently wrong Pareto point.

Rule catalog (DESIGN.md §5):

ERROR (structural soundness — fatal under any checking mode)
  node-index    ``nodes[i].id == i`` (flat topo list is positional)
  arity         per-opcode argument count (ARGMAX: >= 1 logits)
  topo          every arg references a strictly earlier node
  shift         SHL shift >= 0; TRUNC shift >= 1 (0 is the identity and
                must not materialize a node)
  interval      ``lo <= hi`` and the stored interval equals the opcode's
                interval semantics re-derived from the operand intervals
  err           ``err_lo <= err_hi``; CONST/INPUT/ARGMAX carry no local
                error (a deduplicated constant would leak its annotation
                into every user; the ADC and the decision node are exact)
  levels        `Netlist.levels()` matches an independent re-derivation
                (partition of all ids, level = 1 + max over args)
  depths        `critical_path_levels()` matches the documented delay
                semantics (wires +0, gates +1, ARGMAX ceil(log2 n))
  const-dedup   no two CONST nodes share a value (the builder's cache
                invariant — shared constants are one wire pattern)
  bookkeeping   pre/output/input/argmax ids in range and of the right op;
                ``output_ids == layer_pre_ids[-1]``; one ``w_bits`` entry
                per lowered layer; every INPUT registered
  argmax        at most one ARGMAX node, ``argmax_id`` points at it, and
                it is terminal (nothing consumes the class index)
  width-budget  max derived width <= 62 bits (the exact int64 simulation
                budget; `Netlist.validate` maps this rule to the
                historical OverflowError)

WARN (microarchitectural conventions — fatal only under ``strict=True``,
      which is how compiler/pass outputs are checked; hand-built test
      netlists remain legal under the default mode)
  role          op/role legality (SHL only inside multiplier subnets,
                ADD only as mult/tree/bias, RELU tagged relu, ...);
                layer index within the lowered range; CONST tags canonical
  trunc-prov    TRUNC only at the approximation sites (product roots /
                argmax comparator inputs) — exact lowering never emits it
  pre-node      every ``layer_pre_ids[i][k]`` is the neuron's bias ADD
                (role bias, layer i, unit (k,))
  argmax-feed   argmax operands are the logits, possibly through an
                explicit TRUNC chain (comparator-input truncation)

Opt-in modes
  expect_exact  the netlist claims to be exact: any TRUNC node or nonzero
                err annotation is an ERROR (rule ``exact``)
  expect_dce    the netlist claims to be DCE-clean: every node must be
                reachable from the observation points (rule ``dead-code``)
"""
from __future__ import annotations

import math
from typing import List, Optional

from repro.circuit import ir
from repro.verify.diagnostics import (ERROR, WARN, Diagnostic,
                                      VerificationError, errors)

SIM_WIDTH_BUDGET = 62

_ARITY = {
    ir.Op.CONST: 0, ir.Op.INPUT: 0, ir.Op.SHL: 1, ir.Op.ADD: 2,
    ir.Op.SUB: 2, ir.Op.NEG: 1, ir.Op.RELU: 1, ir.Op.TRUNC: 1,
}

# op -> legal roles in anything the compiler or the sanctioned passes emit
_OP_ROLES = {
    ir.Op.CONST: {ir.ROLE_CONST},
    ir.Op.INPUT: {ir.ROLE_INPUT},
    ir.Op.SHL: {ir.ROLE_MULT},
    ir.Op.ADD: {ir.ROLE_MULT, ir.ROLE_TREE, ir.ROLE_BIAS},
    ir.Op.SUB: {ir.ROLE_MULT},
    ir.Op.NEG: {ir.ROLE_MULT},
    ir.Op.RELU: {ir.ROLE_RELU},
    ir.Op.TRUNC: {ir.ROLE_MULT, ir.ROLE_ARGMAX},
    ir.Op.ARGMAX: {ir.ROLE_ARGMAX},
}


def _prov(n: ir.Node) -> str:
    return (f"op={n.op.name} role={n.role} layer={n.layer} "
            f"unit={n.unit}")


def _bits(lo: int, hi: int) -> int:
    """Independent re-derivation of the minimal two's-complement width."""
    bits_hi = hi.bit_length() + 1 if hi > 0 else 1
    bits_lo = (-lo - 1).bit_length() + 1 if lo < 0 else 1
    return max(bits_hi, bits_lo)


def node_widths(net: ir.Netlist) -> List[int]:
    """Per-node minimal two's-complement widths of the datapath words,
    re-derived from the stored intervals (the ARGMAX comparator emits a
    class index, not a datapath word, so it is excluded — same population
    as the 62-bit sim-budget check below)."""
    return [_bits(n.lo, n.hi) for n in net.nodes if n.op != ir.Op.ARGMAX]


def max_sim_width(net: ir.Netlist) -> int:
    """Widest datapath word a simulator lane must hold for this net."""
    ws = node_widths(net)
    return max(ws) if ws else 1


def fits_int32(net: ir.Netlist) -> bool:
    """True when every datapath word fits an int32 lane. The bound is per
    node and inclusive at 32: a width-32 two's-complement interval is
    exactly [-2^31, 2^31 - 1], i.e. the int32 range — simulators used to
    promote such nets to int64 off a ``> 31`` whole-net check and pay for
    64-bit lanes they never needed."""
    return max_sim_width(net) <= 32


def _expected_interval(net: ir.Netlist, n: ir.Node):
    """Re-derive a node's value interval from its operands' stored
    intervals per the documented opcode semantics. Returns None when the
    semantics do not constrain it from here (unknown op)."""
    a = net.nodes[n.args[0]] if n.args else None
    if n.op == ir.Op.CONST:
        return n.value, n.value
    if n.op == ir.Op.INPUT:
        return 0, (1 << net.in_bits) - 1
    if n.op in (ir.Op.SHL, ir.Op.TRUNC) and n.shift < 0:
        return None                    # the shift rule already fired
    if n.op == ir.Op.SHL:
        return a.lo << n.shift, a.hi << n.shift
    if n.op == ir.Op.TRUNC:
        return (a.lo >> n.shift) << n.shift, (a.hi >> n.shift) << n.shift
    if n.op == ir.Op.ADD:
        b = net.nodes[n.args[1]]
        return a.lo + b.lo, a.hi + b.hi
    if n.op == ir.Op.SUB:
        b = net.nodes[n.args[1]]
        return a.lo - b.hi, a.hi - b.lo
    if n.op == ir.Op.NEG:
        return -a.hi, -a.lo
    if n.op == ir.Op.RELU:
        return max(a.lo, 0), max(a.hi, 0)
    if n.op == ir.Op.ARGMAX:
        return 0, len(n.args) - 1
    return None


def verify_netlist(net: ir.Netlist, *, expect_exact: bool = False,
                   expect_dce: bool = False) -> List[Diagnostic]:
    """Run every rule; return all findings (ERROR and WARN severity)."""
    out: List[Diagnostic] = []
    N = len(net.nodes)

    def diag(sev, rule, msg, n: Optional[ir.Node] = None):
        out.append(Diagnostic(sev, rule, msg,
                              node=None if n is None else n.id,
                              provenance="" if n is None else _prov(n)))

    # ---- per-node structural rules ---------------------------------------
    sane_args = [False] * N            # args valid -> later rules may deref
    for i, n in enumerate(net.nodes):
        if n.id != i:
            diag(ERROR, "node-index", f"node at position {i} has id {n.id}",
                 n)
            continue
        want = _ARITY.get(n.op)
        if n.op == ir.Op.ARGMAX:
            if len(n.args) < 1:
                diag(ERROR, "arity", "ARGMAX over an empty logit list", n)
                continue
        elif want is None:
            diag(ERROR, "arity", f"unknown opcode {n.op!r}", n)
            continue
        elif len(n.args) != want:
            diag(ERROR, "arity",
                 f"{n.op.name} takes {want} arg(s), has {len(n.args)}", n)
            continue
        bad = [a for a in n.args if not (0 <= a < n.id)]
        if bad:
            diag(ERROR, "topo",
                 f"arg(s) {bad} not strictly earlier than node {n.id} "
                 "(dangling reference or cycle)", n)
            continue
        sane_args[i] = True

    for i, n in enumerate(net.nodes):
        if not sane_args[i]:
            continue
        if n.op == ir.Op.SHL and n.shift < 0:
            diag(ERROR, "shift", f"negative SHL shift {n.shift}", n)
        if n.op == ir.Op.TRUNC and n.shift < 1:
            diag(ERROR, "shift",
                 f"TRUNC shift {n.shift} (0 is the identity and must not "
                 "materialize a node)", n)
        if n.lo > n.hi:
            diag(ERROR, "interval", f"empty interval [{n.lo}, {n.hi}]", n)
        elif all(sane_args[a] for a in n.args):
            exp = _expected_interval(net, n)
            if exp is not None and exp != (n.lo, n.hi):
                diag(ERROR, "interval",
                     f"stored interval [{n.lo}, {n.hi}] != re-derived "
                     f"[{exp[0]}, {exp[1]}]", n)
        if n.err_lo > n.err_hi:
            diag(ERROR, "err",
                 f"empty error interval [{n.err_lo}, {n.err_hi}]", n)
        if (n.op in (ir.Op.CONST, ir.Op.INPUT, ir.Op.ARGMAX)
                and (n.err_lo, n.err_hi) != (0, 0)):
            diag(ERROR, "err",
                 f"{n.op.name} carries a local error annotation "
                 f"[{n.err_lo}, {n.err_hi}] (deduplicated constants would "
                 "leak it; ADC/decision nodes are exact by definition)", n)

    structurally_sound = all(sane_args) and not errors(out)

    # ---- derived-analysis consistency (only meaningful on sound graphs) --
    if structurally_sound and N:
        lev = [0] * N
        depth = [0] * N
        for n in net.nodes:
            lev[n.id] = 1 + max((lev[a] for a in n.args), default=-1) \
                if n.args else 0
            d = max((depth[a] for a in n.args), default=0)
            if n.op in (ir.Op.ADD, ir.Op.SUB, ir.Op.NEG, ir.Op.RELU):
                d += 1
            elif n.op == ir.Op.ARGMAX:
                d += max(math.ceil(math.log2(max(len(n.args), 2))), 1)
            depth[n.id] = d
        got = net.levels()
        want_levels: List[List[int]] = [[] for _ in range(max(lev) + 1)]
        for i, l in enumerate(lev):
            want_levels[l].append(i)
        if [sorted(g) for g in got] != want_levels:
            diag(ERROR, "levels",
                 "Netlist.levels() disagrees with the re-derived "
                 "topological levels")
        if net.critical_path_levels() != max(depth):
            diag(ERROR, "depths",
                 f"critical_path_levels() = {net.critical_path_levels()} "
                 f"but re-derived delay semantics give {max(depth)}")

        seen_const = {}
        for n in net.nodes:
            if n.op == ir.Op.CONST:
                if n.value in seen_const:
                    diag(ERROR, "const-dedup",
                         f"CONST value {n.value} duplicated at nodes "
                         f"{seen_const[n.value]} and {n.id}", n)
                else:
                    seen_const[n.value] = n.id

    # width budget reads only each node's *stored* interval, so it runs
    # even on graphs with other structural findings (an overflowing node
    # must surface as such, not hide behind a stale consumer interval)
    widths = [_bits(n.lo, n.hi) for n in net.nodes
              if isinstance(n.lo, int) and isinstance(n.hi, int)
              and n.lo <= n.hi]
    if widths and max(widths) > SIM_WIDTH_BUDGET:
        diag(ERROR, "width-budget",
             f"netlist width {max(widths)} exceeds the {SIM_WIDTH_BUDGET}"
             "-bit exact simulation budget (degenerate scale chain?)")

    # ---- classifier bookkeeping ------------------------------------------
    def in_range(i) -> bool:
        return isinstance(i, int) and 0 <= i < N

    if not net.layer_pre_ids:
        diag(ERROR, "bookkeeping", "no layers lowered (layer_pre_ids empty)")
    if len(net.w_bits) != len(net.layer_pre_ids):
        diag(ERROR, "bookkeeping",
             f"{len(net.w_bits)} w_bits entries for "
             f"{len(net.layer_pre_ids)} lowered layers")
    flat_ok = True
    for li, layer in enumerate(net.layer_pre_ids):
        for p in layer:
            if not in_range(p):
                diag(ERROR, "bookkeeping",
                     f"layer_pre_ids[{li}] references node {p} "
                     f"outside [0, {N})")
                flat_ok = False
    if net.layer_pre_ids and net.output_ids != net.layer_pre_ids[-1]:
        diag(ERROR, "bookkeeping",
             "output_ids != layer_pre_ids[-1] (the logits are the last "
             "layer's pre-activations)")
    for i in net.output_ids:
        if not in_range(i):
            diag(ERROR, "bookkeeping",
                 f"output_ids references node {i} outside [0, {N})")
            flat_ok = False
    input_nodes = [n.id for n in net.nodes if n.op == ir.Op.INPUT]
    if structurally_sound and sorted(net.input_ids) != input_nodes:
        diag(ERROR, "bookkeeping",
             f"input_ids {net.input_ids} does not cover the INPUT nodes "
             f"{input_nodes} exactly")

    # ---- argmax terminality / uniqueness ---------------------------------
    if structurally_sound:
        am_nodes = [n.id for n in net.nodes if n.op == ir.Op.ARGMAX]
        if len(am_nodes) > 1:
            diag(ERROR, "argmax", f"multiple ARGMAX nodes {am_nodes}")
        if net.argmax_id is not None:
            if not in_range(net.argmax_id):
                diag(ERROR, "argmax",
                     f"argmax_id {net.argmax_id} outside [0, {N})")
            elif net.nodes[net.argmax_id].op != ir.Op.ARGMAX:
                diag(ERROR, "argmax",
                     f"argmax_id {net.argmax_id} is a "
                     f"{net.nodes[net.argmax_id].op.name} node, not ARGMAX",
                     net.nodes[net.argmax_id])
        elif am_nodes:
            diag(ERROR, "argmax",
                 f"ARGMAX node {am_nodes[0]} exists but argmax_id is None")
        for n in net.nodes:
            users = [a for a in n.args
                     if a < N and net.nodes[a].op == ir.Op.ARGMAX]
            if users:
                diag(ERROR, "argmax",
                     f"node {n.id} consumes ARGMAX output {users} — the "
                     "class index is terminal", n)

    # ---- convention (WARN) rules -----------------------------------------
    if structurally_sound and flat_ok:
        L = len(net.layer_pre_ids)
        for n in net.nodes:
            legal = _OP_ROLES.get(n.op, set())
            if n.role not in legal:
                diag(WARN, "role",
                     f"role {n.role!r} illegal for {n.op.name} "
                     f"(expected one of {sorted(legal)})", n)
            if not (-1 <= n.layer <= max(L - 1, -1)):
                diag(WARN, "role",
                     f"layer {n.layer} outside the lowered range "
                     f"[-1, {L - 1}]", n)
            if n.op == ir.Op.CONST and (n.role, n.layer, n.unit) != (
                    ir.ROLE_CONST, -1, ()):
                diag(WARN, "role",
                     "shared CONST must carry the canonical tags "
                     "(role=const, layer=-1, unit=()) — it is one wire "
                     "pattern owned by no layer", n)
            if n.op == ir.Op.TRUNC and n.role not in (ir.ROLE_MULT,
                                                      ir.ROLE_ARGMAX):
                diag(WARN, "trunc-prov",
                     "TRUNC outside the approximation sites (product "
                     "roots / argmax comparator inputs)", n)
        for li, layer in enumerate(net.layer_pre_ids):
            for k, p in enumerate(layer):
                pn = net.nodes[p]
                if (pn.op != ir.Op.ADD or pn.role != ir.ROLE_BIAS
                        or pn.layer != li or pn.unit != (k,)):
                    diag(WARN, "pre-node",
                         f"layer_pre_ids[{li}][{k}] is not that neuron's "
                         "bias ADD (op=ADD role=bias layer=i unit=(k,))",
                         pn)
        if net.argmax_id is not None and in_range(net.argmax_id):
            outs = set(net.output_ids)
            for a in net.nodes[net.argmax_id].args:
                root = a
                while (net.nodes[root].op == ir.Op.TRUNC
                       and net.nodes[root].args):
                    root = net.nodes[root].args[0]
                if root not in outs:
                    diag(WARN, "argmax-feed",
                         f"argmax operand {a} is not a logit (or a TRUNC "
                         "chain over one)", net.nodes[a])

    # ---- opt-in modes ----------------------------------------------------
    if expect_exact and structurally_sound:
        for n in net.nodes:
            if n.op == ir.Op.TRUNC:
                diag(ERROR, "exact",
                     "TRUNC in a netlist claimed exact (only the "
                     "approximation passes emit it)", n)
            if (n.err_lo, n.err_hi) != (0, 0):
                diag(ERROR, "exact",
                     f"error annotation [{n.err_lo}, {n.err_hi}] in a "
                     "netlist claimed exact", n)

    if expect_dce and structurally_sound and flat_ok:
        # independent live-set walk (same observation points as the DCE:
        # argmax, logits, every layer's pre nodes, every ADC input lane,
        # and every activation node — a fully-fanout-pruned neuron still
        # prints its ReLU, per the PR 3 layer-interface convention)
        live = set()
        stack = list(net.input_ids) + list(net.output_ids)
        if net.argmax_id is not None:
            stack.append(net.argmax_id)
        for layer in net.layer_pre_ids:
            stack.extend(layer)
        stack.extend(n.id for n in net.nodes if n.op == ir.Op.RELU)
        while stack:
            i = stack.pop()
            if i in live or not in_range(i):
                continue
            live.add(i)
            stack.extend(net.nodes[i].args)
        dead = [n.id for n in net.nodes if n.id not in live]
        if dead:
            diag(ERROR, "dead-code",
                 f"{len(dead)} unreachable node(s) in a netlist claimed "
                 f"DCE-clean (first few: {dead[:8]})")

    return out


def check_netlist(net: ir.Netlist, *, strict: bool = False,
                  expect_exact: bool = False,
                  expect_dce: bool = False) -> List[Diagnostic]:
    """Verify and raise on fatal findings. Non-strict raises only on
    ERROR-severity (structural) findings; ``strict=True`` — the mode the
    compiler and pass pipeline use on their own outputs — also promotes
    the convention (WARN) rules to fatal. Returns all diagnostics when
    nothing is fatal. The historical `OverflowError` contract of
    `Netlist.validate` is preserved for the width-budget rule."""
    diags = verify_netlist(net, expect_exact=expect_exact,
                           expect_dce=expect_dce)
    fatal = diags if strict else errors(diags)
    if fatal:
        if all(d.rule == "width-budget" for d in fatal):
            raise OverflowError(fatal[0].message)
        raise VerificationError(fatal)
    return diags
