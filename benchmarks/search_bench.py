"""Search-runtime benchmark: island fleet throughput, checkpoint cost,
resume overhead.

Three questions about `repro.search` (the fault-tolerant island-model
NSGA-II runtime), answered on a synthetic evaluator so the numbers isolate
the *runtime* — not QAT — cost:

* **throughput** — fleet rounds/s (one round = one generation on every
  island) with checkpointing off;
* **checkpoint overhead** — extra wall-clock per round with
  ``checkpoint_every=1`` (full search-state snapshot through
  `ckpt.CheckpointManager` every round);
* **resume overhead** — wall-clock to restore a preempted search from its
  snapshot and drive it to the same final round, vs what the uninterrupted
  run spent on those remaining rounds. Bit-identity of the resumed Pareto
  front is asserted, not assumed.

The non-``--fast`` mode adds a real-evaluator data point: a small seeds-MLP
search through `batch_eval.make_batch_evaluator` with a warm `EvalCache`,
reporting steady-state generations/s of the full stack.

A fourth question covers the observability layer (`repro.obs`): the same
synthetic fleet is driven untraced and under a live tracer (best-of-N
each), the relative overhead is asserted under 3%, and the off-path is
held to its contract — no `Tracer` is ever constructed when tracing is
off (all obs file IO flows through `Tracer`, so zero instances means zero
extra syscalls).
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path
from typing import Dict

import numpy as np

from repro.core.ga import GAConfig
from repro.obs import trace as TR
from repro.search import (IslandConfig, PreemptedError, SearchConfig,
                          SearchRuntime)
from repro.search.faults import FaultHarness, FaultPlan


def _synthetic(spec):
    bits = sum(l.bits for l in spec.layers)
    sp = sum(l.sparsity for l in spec.layers)
    return (bits / 16.0, sp)


def _cfg(rounds: int, population: int, islands: int,
         checkpoint_every: int = 0) -> SearchConfig:
    return SearchConfig(
        n_layers=2, rounds=rounds,
        ga=GAConfig(population=population, seed=7),
        islands=IslandConfig(n_islands=islands, migration_every=2,
                             migrants=2),
        checkpoint_every=checkpoint_every)


def tracing_overhead(*, rounds: int = 16, population: int = 16,
                     islands: int = 4, repeats: int = 5) -> Dict:
    """Traced-vs-untraced fleet wall-clock as the minimum over ``repeats``
    *paired* (untraced, traced) back-to-back laps of each pair's ratio.

    Laps are short (~1s) and shared/noisy hosts jitter wall-clock by
    +-15% per lap — far above the true tracing cost — with slow windows
    (disk stalls, co-tenant bursts) that can cover a whole phase-separated
    batch and fake a double-digit "overhead". Pairing puts both sides of
    each ratio into the same noise window, and one clean pair suffices for
    an honest minimum; the gate then measures tracing, not the weather.

    The untraced laps run under an instrumented ``Tracer.__init__`` so the
    zero-syscalls-when-off contract is checked, not assumed: any Tracer
    constructed while the flag is off is a bug (there is no other path to
    obs file IO)."""
    cfg = lambda: _cfg(rounds, population, islands)  # noqa: E731

    def lap() -> float:
        t0 = time.perf_counter()
        SearchRuntime(cfg(), evaluate=_synthetic).run()
        return time.perf_counter() - t0

    constructed: list = []
    init = TR.Tracer.__init__

    def counting_init(self, path):
        constructed.append(str(path))
        init(self, path)

    td = Path(tempfile.mkdtemp(prefix="repro_obs_bench_"))
    trace_path = td / "search_bench_trace.jsonl"
    pairs = []
    assert not TR.active(), "bench must start with tracing off"
    for i in range(repeats):
        TR.Tracer.__init__ = counting_init
        try:
            t_off = lap()
            assert not constructed, \
                f"Tracer constructed with tracing off: {constructed}"
        finally:
            TR.Tracer.__init__ = init
        p = td / f"lap{i}.jsonl" if i < repeats - 1 else trace_path
        with TR.capture(p):
            t_on = lap()
        pairs.append((t_off, t_on))
    records, damaged = TR.read_trace(trace_path)
    assert damaged == 0 and records, "bench trace unreadable"
    t_off, t_on = min(pairs, key=lambda p: p[1] / p[0])
    overhead = max(0.0, t_on / t_off - 1.0)
    return {
        "t_untraced_s": t_off, "t_traced_s": t_on,
        "overhead_pct": overhead * 100.0,
        "trace_path": str(trace_path), "trace_records": len(records),
    }


def run(*, rounds: int = 16, population: int = 16,
        islands: int = 4, real: bool = False) -> Dict:
    # throughput, checkpointing off
    t0 = time.time()
    base = SearchRuntime(_cfg(rounds, population, islands),
                         evaluate=_synthetic).run()
    t_plain = time.time() - t0

    with tempfile.TemporaryDirectory() as td:
        # per-round checkpoint cost
        t0 = time.time()
        SearchRuntime(_cfg(rounds, population, islands, checkpoint_every=1),
                      evaluate=_synthetic, ckpt_root=Path(td) / "a").run()
        t_ckpt = time.time() - t0

        # preempt halfway, restore, finish — resumed front must be
        # bit-identical to the uninterrupted run's
        half = rounds // 2
        rt = SearchRuntime(_cfg(rounds, population, islands),
                           evaluate=_synthetic, ckpt_root=Path(td) / "b",
                           harness=FaultHarness(FaultPlan(preempt_at=half - 1)))
        try:
            rt.run()
        except PreemptedError:
            pass
        t0 = time.time()
        rt2 = SearchRuntime.resume(_cfg(rounds, population, islands),
                                   Path(td) / "b", evaluate=_synthetic)
        t_restore = time.time() - t0
        t0 = time.time()
        res = rt2.run()
        t_finish = time.time() - t0
    assert [s.to_json() for s in res.front_specs] == \
        [s.to_json() for s in base.front_specs], "resume not bit-identical"
    np.testing.assert_array_equal(res.front_objectives,
                                  base.front_objectives)

    out = {
        "rounds": rounds, "population": population, "islands": islands,
        "rounds_per_s": rounds / t_plain,
        "ckpt_overhead_ms_per_round": (t_ckpt - t_plain) / rounds * 1e3,
        "restore_s": t_restore,
        # uninterrupted run spends ~ t_plain/2 on the back half; anything
        # beyond that in restore+finish is the price of the preemption
        "resume_overhead_s": t_restore + t_finish - t_plain * (1 - half / rounds),
    }

    if real:
        from repro.configs.printed_mlp import PRINTED_MLPS
        from repro.core import batch_eval as BE
        with tempfile.TemporaryDirectory() as td:
            mlp = PRINTED_MLPS["seeds"]
            scfg = SearchConfig(
                n_layers=len(mlp.layer_dims) - 1, rounds=4,
                ga=GAConfig(population=8, seed=7,
                            input_bits=mlp.input_bits),
                islands=IslandConfig(n_islands=2, migration_every=2))

            def fresh():
                cache = BE.EvalCache(Path(td) / "evals.json")
                be = BE.make_batch_evaluator(mlp, epochs=30, seed=0,
                                             cache=cache)
                return SearchRuntime(scfg, batch_evaluate=be,
                                     eval_cache=cache)

            t0 = time.time()
            fresh().run()                  # cold: jit compiles + QAT
            t_cold = time.time() - t0
            t0 = time.time()
            fresh().run()                  # warm: pure EvalCache replay
            t_warm = time.time() - t0
        out.update(real_cold_s_per_round=t_cold / scfg.rounds,
                   real_warm_s_per_round=t_warm / scfg.rounds)
    return out


def main(fast: bool = False):
    res = run(real=not fast)
    res.update(tracing_overhead())
    print("search_bench (island-model runtime: throughput / checkpoint / "
          "resume)")
    print(f"islands={res['islands']} population={res['population']} "
          f"rounds={res['rounds']} (synthetic evaluator)")
    print(f"  throughput         {res['rounds_per_s']:8.1f} rounds/s")
    print(f"  checkpoint         {res['ckpt_overhead_ms_per_round']:8.2f} "
          "ms/round overhead (checkpoint_every=1)")
    print(f"  restore            {res['restore_s'] * 1e3:8.2f} ms")
    print(f"  resume overhead    {res['resume_overhead_s'] * 1e3:8.2f} ms "
          "(restore + finish - uninterrupted back half)")
    if "real_cold_s_per_round" in res:
        print(f"  real seeds search  {res['real_cold_s_per_round']:8.1f} "
              "s/round cold, "
              f"{res['real_warm_s_per_round']:8.2f} s/round warm "
              "(EvalCache replay)")
    print(f"  tracing overhead   {res['overhead_pct']:8.2f} % "
          f"({res['trace_records']} records -> {res['trace_path']})")
    assert res["overhead_pct"] < 3.0, \
        f"tracing overhead {res['overhead_pct']:.2f}% exceeds the 3% budget"
    print("  tracing overhead < 3%: PASS (0 Tracer instances when off)")
    print("  resumed Pareto front bit-identical: PASS")
    return res


if __name__ == "__main__":
    main()
