"""whisper-base [audio] — enc-dec backbone; conv frontend is a STUB
(input_specs feeds precomputed frame embeddings). [arXiv:2212.04356]"""
from repro.configs.base import ArchConfig, EncoderConfig, LayerSpec, Segment

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    d_model=512,
    vocab_size=51865,
    # decoder: 6 layers, each self-attn + cross-attn to encoder frames
    segments=(Segment((LayerSpec("cross", "dense"),), 6),),
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    mlp_type="gelu",
    norm_type="layernorm",
    use_rope=False,
    max_position_embeddings=32768,     # backbone shapes go to 32k (assigned
    # decode_32k); real whisper caps at 448 — noted backbone-only semantics
    encoder=EncoderConfig(num_layers=6, num_frames=1500),
    source="arXiv:2212.04356; unverified",
    notes="encoder-decoder: decode shapes exercise the decoder with "
          "cross-attention to stub frame embeddings",
)
