"""Flash attention (online softmax), causal + sliding-window.

The memory-roofline fix for the attention-heavy cells: the (bq, bk) score
tile lives only in VMEM — HBM never sees the O(T*S) score matrix that
dominates `bytes accessed` in the chunked-jnp path (EXPERIMENTS.md §Perf).

Grid: (B*H, T/bq, S/bk), k innermost. Causal block skipping: KV blocks
strictly above the diagonal (and, with a window, strictly below the band)
contribute nothing and are skipped via pl.when — FLOPs drop ~2x for causal,
~T/(2W)x for sliding windows.

Running max m, denominator l and output accumulator live in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  k_steps: int, block_q: int, block_k: int, causal: bool,
                  window: int, scale: float, softcap: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # visibility of this KV block for this Q block
    visible = True
    if causal:
        visible = k_start <= q_start + block_q - 1
    if window:
        visible = jnp.logical_and(
            visible, k_start + block_k - 1 > q_start - window)

    @pl.when(visible)
    def _attend():
        q = q_ref[0].astype(jnp.float32)                   # (bq, d)
        k = k_ref[0].astype(jnp.float32)                   # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        ok = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            ok &= k_pos <= q_pos
        if window:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                                # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                             # (bq, bk)
        p = jnp.where(ok, p, 0.0)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                   # (bk, d)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == k_steps - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0, block_q: int = 128,
                           block_k: int = 128, interpret: bool = False):
    """q: (BH, T, d); k/v: (BH, S, d). GQA callers fold/broadcast heads in
    ops.py. T % block_q == 0, S % block_k == 0 (ops.py pads)."""
    BH, T, d = q.shape
    S = k.shape[1]
    assert T % block_q == 0 and S % block_k == 0
    k_steps = S // block_k
    grid = (BH, T // block_q, k_steps)
    scale = d ** -0.5

    return pl.pallas_call(
        functools.partial(_flash_kernel, k_steps=k_steps, block_q=block_q,
                          block_k=block_k, causal=causal, window=window,
                          scale=scale, softcap=softcap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
