"""Full paper reproduction, one dataset: the WhiteWine classifier with the
hardware-aware GA (paper Fig. 2), smaller budget than the benchmark version.

Run:  PYTHONPATH=src python examples/printed_mlp_minimization.py
"""
from benchmarks import fig2_combined

if __name__ == "__main__":
    fig2_combined.main(fast=True)
