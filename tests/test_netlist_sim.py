"""Population-batched netlist simulation (`repro.kernels.netlist_sim`):
packing round-trips, padded mixed-size populations, bit-exactness of every
engine against `circuit.simulate`, lane-width selection off the verifier's
per-node bounds, and the batched/serial/fallback wiring in
`core.batch_eval`."""
import numpy as np
import pytest

from repro import circuit
from repro.circuit import ir
from repro.circuit.simulate import Simulator
from repro.configs import backend
from repro.configs.printed_mlp import PRINTED_MLPS
from repro.core import batch_eval as BE
from repro.core import minimize as MZ
from repro.core.compression_spec import ModelMin
from repro.kernels import netlist_sim as NS
from repro.verify.netlist import max_sim_width

from _hypothesis_compat import given, settings, st
from test_circuit import synth_compiled

RNG = np.random.default_rng(7)


def _synth_net(dims, bits=5, *, sparsity=0.0, clusters=None, seed=0):
    c = synth_compiled(dims, bits, sparsity=sparsity, clusters=clusters,
                       seed=seed)
    return circuit.compile_netlist(c)


def _assert_candidate_matches_serial(out, p, net, x):
    serial = Simulator(net).run(x)
    assert np.array_equal(out["argmax"][p], serial["argmax"])
    # exact netlists: the comparator operands ARE the output logits
    assert np.array_equal(out["amx"][p], serial["logits"].astype(np.int64))


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------


def _assert_round_trip(pop, p, net):
    rt = NS.unpack_netlist(pop, p)
    assert set(rt) == set(range(len(net.nodes)))
    for nid, (o, args, sh, v) in rt.items():
        nd = net.nodes[nid]
        assert o == int(nd.op)
        if nd.op == ir.Op.ARGMAX:
            assert args == tuple(nd.args)
        elif nd.op in (ir.Op.SHL, ir.Op.TRUNC):
            assert args == (nd.args[0],) and sh == nd.shift
        elif nd.op in (ir.Op.ADD, ir.Op.SUB):
            assert args == tuple(nd.args)
        elif nd.op in (ir.Op.NEG, ir.Op.RELU):
            assert args == (nd.args[0],)
        elif nd.op == ir.Op.CONST:
            assert v == nd.value


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6))
def test_pack_unpack_round_trip(seed):
    """pack -> unpack reproduces every node's (op, args, shift, value) on
    randomized architectures — a lossy packer would silently simulate a
    different circuit."""
    r = np.random.default_rng(seed)
    dims = (int(r.integers(3, 10)), int(r.integers(3, 12)),
            int(r.integers(2, 6)))
    net = _synth_net(dims, int(r.integers(2, 7)),
                     sparsity=float(r.uniform(0.0, 0.6)),
                     clusters=int(r.integers(2, 6)) if r.random() < 0.5
                     else None,
                     seed=seed % 997)
    small = _synth_net((dims[0], 3, dims[-1]), 3, seed=seed % 991)
    pop = NS.pack_population([net, small])     # padded stacking too
    _assert_round_trip(pop, 0, net)
    _assert_round_trip(pop, 1, small)


def test_pack_rejects_mixed_arity():
    a = _synth_net((5, 4, 3))
    b = _synth_net((6, 4, 3))
    with pytest.raises(ValueError, match="mixed arities"):
        NS.pack_population([a, b])


# ---------------------------------------------------------------------------
# engines: bit-exactness
# ---------------------------------------------------------------------------


def test_padded_population_mixed_sizes_bit_exact():
    """Candidates of very different node counts share one launch; each is
    bit-exact vs its own serial simulation in every engine."""
    nets = [_synth_net(d, 5, seed=i) for i, d in enumerate(
        [(7, 3, 3), (7, 28, 3), (7, 14, 14, 3), (7, 5, 3)])]
    sizes = [len(n) for n in nets]
    assert max(sizes) / min(sizes) > 3     # genuinely mixed-size launch
    pop = NS.pack_population(nets)
    x = RNG.integers(0, 2 ** 4, size=(23, 7)).astype(np.int64)
    ref = NS.simulate_population_ref(pop, x)
    lev = NS.simulate_population(pop, x, engine="levels")
    pal = NS.simulate_population(pop, x, engine="pallas", interpret=True)
    for p, net in enumerate(nets):
        _assert_candidate_matches_serial(ref, p, net, x)
        assert np.array_equal(lev["amx"][p], ref["amx"][p])
        assert np.array_equal(pal["amx"][p], ref["amx"][p])
        assert np.array_equal(lev["argmax"][p], ref["argmax"][p])
        assert np.array_equal(pal["argmax"][p], ref["argmax"][p])


def test_small_window_many_waves_bit_exact():
    """A tiny wave width forces multi-wave levels (the schedule's chunking
    path) without changing results."""
    nets = [_synth_net((6, 9, 4), 4, seed=s) for s in (0, 1)]
    pop = NS.pack_population(nets)
    x = RNG.integers(0, 2 ** 4, size=(11, 6)).astype(np.int64)
    wide = NS.simulate_population(pop, x, engine="levels", window=512)
    narrow = NS.simulate_population(pop, x, engine="levels", window=8)
    assert np.array_equal(wide["amx"], narrow["amx"])


def test_batch_tiling_bit_exact():
    """B larger than block_b splits into padded tiles that reuse one
    executable; results are unchanged."""
    net = _synth_net((5, 6, 3), 4, seed=2)
    pop = NS.pack_population([net])
    x = RNG.integers(0, 2 ** 4, size=(37, 5)).astype(np.int64)
    whole = NS.simulate_population(pop, x, engine="levels", block_b=2048)
    tiled = NS.simulate_population(pop, x, engine="levels", block_b=16)
    assert np.array_equal(whole["amx"], tiled["amx"])


@pytest.mark.parametrize("dataset", ["seeds", "redwine", "whitewine",
                                     "pendigits"])
def test_population_engine_bit_exact_on_dataset(dataset):
    """The packed engine is bit-exact against `circuit.simulate.simulate`
    on real compiled candidates of all four paper datasets."""
    cfg = PRINTED_MLPS[dataset]
    n = len(cfg.layer_dims) - 1
    params0, (xtr, ytr, xte, yte) = MZ.pretrain(cfg, seed=0)
    specs = [ModelMin.uniform(n, bits=8),
             ModelMin.uniform(n, bits=4, sparsity=0.3)]
    nets, xs = [], []
    for s in specs:
        masks = MZ.make_masks(params0, s)
        params = MZ.qat_finetune(params0, s, masks, xtr, ytr, epochs=10)
        c = MZ.compile_bespoke(params, s, masks)
        nets.append(circuit.compile_netlist(c))
        xs.append(np.asarray(MZ.quantize_inputs(c, xte[:256]), np.int64))
    pop = NS.pack_population(nets)
    out = NS.simulate_population(pop, np.stack(xs), engine="levels")
    for p, net in enumerate(nets):
        serial = circuit.simulate(net, xs[p])    # the acceptance oracle
        assert np.array_equal(out["argmax"][p], serial["argmax"])
        assert np.array_equal(out["amx"][p],
                              serial["logits"].astype(np.int64))
    if dataset == "seeds":                       # pallas parity, cheap case
        pal = NS.simulate_population(pop, np.stack(xs), engine="pallas",
                                     interpret=True)
        assert np.array_equal(pal["amx"], out["amx"])


# ---------------------------------------------------------------------------
# lane widths (satellite: per-node verifier bounds, not whole-net max)
# ---------------------------------------------------------------------------


def _width32_net():
    """Hand-built net whose widest word is exactly width 32 (int32 range):
    255 << 23 = 2139095040 <= 2^31 - 1."""
    net = ir.Netlist(in_bits=8, w_bits=[8])
    a = net.shl(net.input(0), 23)
    b = net.shl(net.input(1), 23)
    net.layer_pre_ids = [[a, b]]
    net.output_ids = [a, b]
    net.argmax([a, b])
    return net


def test_width32_net_stays_int32_and_bit_exact():
    """Width-32 words fit int32 exactly; the old whole-net `> 31` check
    promoted them to 64-bit lanes. Bit-exactness holds on the int32 path
    in the serial simulator and both population engines."""
    net = _width32_net()
    assert max_sim_width(net) == 32
    assert net.max_width > 31              # the old rule would go int64
    sim = Simulator(net)
    assert sim._x64 is False               # the fix: int32 lanes
    x = np.array([[255, 200], [1, 255], [0, 0], [254, 255]], np.int64)
    got = sim.run(x)
    expect = np.stack([x[:, 0] << 23, x[:, 1] << 23], axis=1)
    assert np.array_equal(got["logits"].astype(np.int64), expect)
    pop = NS.pack_population([net])
    assert pop.max_width == 32
    lev = NS.simulate_population(pop, x, engine="levels")
    pal = NS.simulate_population(pop, x, engine="pallas", interpret=True)
    assert np.array_equal(lev["amx"][0], expect)
    assert np.array_equal(pal["amx"][0], expect)
    assert np.array_equal(lev["argmax"][0], got["argmax"])


def test_wide_population_takes_int64_lanes():
    """Past width 32 the levels engine runs int64 (and the pallas route
    falls back to it — TPU Pallas has no int64 lanes), still bit-exact."""
    net = _synth_net((11, 12, 12, 7), 8, seed=3)
    pop = NS.pack_population([net])
    assert pop.max_width > 32
    x = RNG.integers(0, 2 ** 8, size=(9, 11)).astype(np.int64)
    lev = NS.simulate_population(pop, x, engine="levels")
    pal = NS.simulate_population(pop, x, engine="pallas")
    _assert_candidate_matches_serial(lev, 0, net, x)
    assert np.array_equal(pal["amx"], lev["amx"])


def test_engine_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_NETLIST_ENGINE", "ref")
    assert backend.default_netlist_engine() == "ref"
    monkeypatch.delenv("REPRO_NETLIST_ENGINE")
    assert backend.default_netlist_engine() in ("levels", "pallas")


# ---------------------------------------------------------------------------
# batch_eval wiring: default objective, cache keys, fault fallback
# ---------------------------------------------------------------------------


def test_evalcache_keys_byte_stable():
    """Flipping the default objective must not move a single byte of the
    cache keyspace: analytic entries keep their historical keys, netlist
    entries their "|netlist" suffix."""
    s = ModelMin.uniform(2, bits=4)
    base = f"seeds|seed=0|epochs=30|{s.to_json()}"
    assert BE.EvalCache.key("seeds", 0, 30, s) == base
    assert BE.EvalCache.key("seeds", 0, 30, s, netlist=True) == \
        base + "|netlist"


def test_mixed_input_bits_population_matches_serial():
    """Candidates quantizing the ADC lanes at different input_bits get
    per-candidate integer features inside one packed launch; each equals
    its serial netlist-exact evaluation."""
    cfg = PRINTED_MLPS["seeds"]
    n = len(cfg.layer_dims) - 1
    specs = [ModelMin.uniform(n, bits=4, input_bits=4),
             ModelMin.uniform(n, bits=4, input_bits=8)]
    rs = BE.evaluate_population(cfg, specs, epochs=8)
    for s, r in zip(specs, rs):
        assert r.accuracy == MZ.evaluate_spec(cfg, s, epochs=8).accuracy


def test_batched_sim_fault_falls_back_to_serial(monkeypatch):
    """A fault in the batched launch degrades to per-candidate serial
    netlist scoring with identical results — one bad batch must not
    quarantine a healthy generation."""
    cfg = PRINTED_MLPS["seeds"]
    n = len(cfg.layer_dims) - 1
    specs = [ModelMin.uniform(n, bits=8),
             ModelMin.uniform(n, bits=3, sparsity=0.3)]
    expected = BE.evaluate_population(cfg, specs, epochs=8)

    def boom(*a, **k):
        raise RuntimeError("injected batched-sim fault")

    monkeypatch.setattr(BE, "_packed_netlist_for", boom)
    got = BE.evaluate_population(cfg, specs, epochs=8)
    assert [r.accuracy for r in got] == [r.accuracy for r in expected]
    assert [r.area_mm2 for r in got] == [r.area_mm2 for r in expected]


def test_batched_and_serial_sim_fault_quarantines(monkeypatch):
    """When the serial fallback fails too, candidates quarantine with
    worst-case fitness at stage 'score' and are never cached."""
    cfg = PRINTED_MLPS["seeds"]
    n = len(cfg.layer_dims) - 1
    specs = [ModelMin.uniform(n, bits=8)]

    def boom(*a, **k):
        raise RuntimeError("injected sim fault")

    monkeypatch.setattr(BE, "_packed_netlist_for", boom)
    monkeypatch.setattr(circuit, "netlist_accuracy", boom)
    recs = []
    rs = BE.evaluate_population(cfg, specs, epochs=8, quarantine=recs)
    assert len(recs) == 1 and recs[0].stage == "score"
    assert rs[0].accuracy == 0.0
    assert rs[0].area_mm2 == BE.QUARANTINE_AREA_MM2


def test_pack_cache_reuses_tables(monkeypatch):
    calls = {"n": 0}
    real = NS.pack_netlist

    def counting(net):
        calls["n"] += 1
        return real(net)

    monkeypatch.setattr(NS, "pack_netlist", counting)
    BE._PACK_CACHE.clear()
    key = "unit|pack"
    net = _synth_net((5, 4, 3))
    a = BE._packed_netlist_for(key, net, NS)
    b = BE._packed_netlist_for(key, net, NS)
    assert a is b and calls["n"] == 1
    assert BE._packed_netlist_for(None, net, NS) is not a  # uncached path


def test_pack_cache_lru_cap_and_eviction_counter(monkeypatch):
    from repro.obs import metrics as MT
    monkeypatch.setattr(BE, "_PACK_CACHE_CAP", 3)
    BE._PACK_CACHE.clear()
    ev0 = MT.counter("netlist_sim.pack_evictions").value
    net = _synth_net((5, 4, 3))
    for k in ("a", "b", "c"):
        BE._packed_netlist_for(k, net, NS)
    first_a = BE._PACK_CACHE["a"]
    BE._packed_netlist_for("a", net, NS)          # refresh a's recency
    BE._packed_netlist_for("d", net, NS)          # evicts b (LRU), not a
    assert set(BE._PACK_CACHE) == {"a", "c", "d"}
    assert BE._PACK_CACHE["a"] is first_a
    assert MT.counter("netlist_sim.pack_evictions").value == ev0 + 1
    BE._packed_netlist_for("e", net, NS)          # evicts c
    assert set(BE._PACK_CACHE) == {"a", "d", "e"}
    assert MT.counter("netlist_sim.pack_evictions").value == ev0 + 2
    BE._PACK_CACHE.clear()
