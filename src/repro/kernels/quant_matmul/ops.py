"""jit'd wrapper: padding to block multiples + CPU interpret fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quant_matmul.kernel import quant_matmul_pallas
from repro.kernels.quant_matmul.ref import quant_matmul_ref
from repro.obs import prof as PF
from repro.obs import trace as TR


def _pad_to(a, mult, axis):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def _quant_matmul_jit(x, w_q, scales, *, block_m, block_n, block_k,
                      interpret):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    M, N = x.shape[0], w_q.shape[1]
    xp = _pad_to(_pad_to(x, block_m, 0), block_k, 1)
    wp = _pad_to(_pad_to(w_q, block_k, 0), block_n, 1)
    sp = _pad_to(scales, block_n, 0)
    y = quant_matmul_pallas(xp, wp, sp, block_m=block_m, block_n=block_n,
                            block_k=block_k, interpret=interpret)
    return y[:M, :N]


def quant_matmul(x, w_q, scales, *, block_m=128, block_n=128, block_k=128,
                 interpret: bool | None = None):
    """y = x @ dequant(w_q, scales). Shapes padded to block multiples; the
    kernel runs interpret=True off-TPU (correctness path on this container)."""
    if not TR.active():
        return _quant_matmul_jit(x, w_q, scales, block_m=block_m,
                                 block_n=block_n, block_k=block_k,
                                 interpret=interpret)
    key = ("quant_matmul", x.shape, w_q.shape, block_m, block_n, block_k)
    with PF.dispatch("kernels.quant_matmul", key,
                     lower=lambda: _quant_matmul_jit.lower(
                         x, w_q, scales, block_m=block_m, block_n=block_n,
                         block_k=block_k, interpret=interpret),
                     m=x.shape[0], k=x.shape[1], n=w_q.shape[1]):
        y = _quant_matmul_jit(x, w_q, scales, block_m=block_m,
                              block_n=block_n, block_k=block_k,
                              interpret=interpret)
        jax.block_until_ready(y)
    return y


__all__ = ["quant_matmul", "quant_matmul_ref"]
