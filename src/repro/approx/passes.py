"""The approximation passes (Armeniakos DATE'22; Afentaki ICCAD'23 style):

* :class:`RoundCoeffsCSD` — truncated-CSD coefficient rounding: drop the
  ``drop[layer]`` lowest-significance signed digits of every bespoke
  multiplier coefficient (keeping at least the top digit — the power-of-2
  limit case), rebuilding the shift-add subnet from the kept digits. The
  kept top digits of a canonical recoding are themselves canonical (NAF
  uniqueness), so the rebuilt subnet is exactly the truncated network and
  the cost model's CSD counting stays coherent.
* :class:`TruncateAccum` — adder LSB truncation: wrap every product root
  of a layer in a TRUNC that floors away ``lsb[layer]`` low bits, so the
  whole accumulation tree above it narrows (priced by `circuit.cost`'s
  trunc-level discount).
* :class:`SimplifyActs` — comparator/ReLU simplification: ReLUs whose
  pre-activation interval proves a fixed sign collapse to a wire or a
  hardwired zero (exact — applied only when the operand carries no
  accumulated error, otherwise the clipping could hide an error sign
  flip); argmax comparator inputs are truncated by ``argmax_lsb`` bits,
  narrowing the final comparator tree.

All parameters are per-layer, matching the GA's approximation genes
(`compression_spec.LayerMin.csd_drop` / ``.lsb`` and
``ModelMin.argmax_lsb``).
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

from repro.circuit import compile as CC
from repro.circuit import ir
from repro.core import hw_model as HW
from repro.approx.analyze import propagate_errors
from repro.approx.rewrite import Pass, rebuild


def product_info(net: ir.Netlist, root: int) -> Tuple[int, int]:
    """(source activation id, integer coefficient) of one bespoke
    multiplier subnet, derived purely from the graph: the subnet is the
    mult-role nodes sharing the root's (layer, unit); its unique external
    argument is the source; the coefficient is the subnet evaluated
    symbolically at source = 1."""
    rn = net.nodes[root]
    key = (rn.layer, rn.unit)

    def in_subnet(i: int) -> bool:
        n = net.nodes[i]
        return n.role == ir.ROLE_MULT and (n.layer, n.unit) == key

    src = None
    val: Dict[int, int] = {}

    def ev(i: int) -> int:
        nonlocal src
        if i in val:
            return val[i]
        n = net.nodes[i]
        if not in_subnet(i):
            assert src is None or src == i, \
                f"multiplier subnet at {root} has two sources ({src}, {i})"
            src = i
            v = 1
        elif n.op == ir.Op.SHL:
            v = ev(n.args[0]) << n.shift
        elif n.op == ir.Op.ADD:
            v = ev(n.args[0]) + ev(n.args[1])
        elif n.op == ir.Op.SUB:
            v = ev(n.args[0]) - ev(n.args[1])
        elif n.op == ir.Op.NEG:
            v = -ev(n.args[0])
        elif n.op == ir.Op.TRUNC:        # pre-truncated subnet: treat as wire
            v = ev(n.args[0])
        else:
            raise ValueError(f"unexpected {n.op} inside multiplier subnet")
        val[i] = v
        return v

    coeff = ev(root)
    assert src is not None and coeff != 0, (root, coeff)
    return src, coeff


def truncate_csd(coeff: int, drop: int) -> int:
    """Drop the ``drop`` lowest-significance CSD digits of ``coeff``,
    always keeping the top digit (a zero coefficient would change the
    netlist's *structure*, which is pruning's job, not rounding's)."""
    digits = sorted(HW.csd_digits(coeff))            # ascending shift
    keep = max(len(digits) - max(drop, 0), 1)
    return sum(s << p for p, s in digits[len(digits) - keep:])


class RoundCoeffsCSD(Pass):
    """Truncated-CSD / power-of-2 coefficient rounding, per layer."""

    name = "round-coeffs-csd"
    monotone_cost = True      # dropped digits = fewer SHL wires / gates
    monotone_bound = True     # adds declared local error, removes none

    def __init__(self, drop: Sequence[int]):
        self.drop = [int(d) for d in drop]

    def run(self, net: ir.Netlist) -> ir.Netlist:
        errs = propagate_errors(net)

        def rw(new, old, n, m):
            if not (n.product_root and n.role == ir.ROLE_MULT):
                return None
            drop = self.drop[n.layer] if 0 <= n.layer < len(self.drop) else 0
            if drop <= 0:
                return None
            src, coeff = product_info(old, n.id)
            c2 = truncate_csd(coeff, drop)
            if c2 == coeff:
                return None
            root = CC._lower_const_mult(new, m[src], c2, layer=n.layer,
                                        unit=n.unit)
            # local error: the rebuilt subnet already propagates the
            # source's accumulated error scaled by the NEW coefficient;
            # what it cannot see is (c2 - coeff) * x_exact, with the exact
            # source value bounded by the approx interval minus its error
            d = c2 - coeff
            el, eh = errs[src]
            sn = old.nodes[src]
            xlo, xhi = sn.lo - eh, sn.hi - el
            node = new.nodes[root]
            node.err_lo += min(d * xlo, d * xhi)
            node.err_hi += max(d * xlo, d * xhi)
            return root

        return rebuild(net, rw)


class TruncateAccum(Pass):
    """Adder LSB truncation: floor away ``lsb[layer]`` low bits of every
    product entering the layer's accumulation trees."""

    name = "truncate-accum"
    monotone_cost = True      # TRUNC is free wiring; adders only narrow
    monotone_bound = True     # TRUNC's intrinsic error is a superset

    def __init__(self, lsb: Sequence[int]):
        self.lsb = [int(b) for b in lsb]

    def run(self, net: ir.Netlist) -> ir.Netlist:
        def rw(new, old, n, m):
            if not (n.product_root and n.role == ir.ROLE_MULT):
                return None
            k = self.lsb[n.layer] if 0 <= n.layer < len(self.lsb) else 0
            if k <= 0:
                return None
            from repro.approx.rewrite import copy_node
            root = copy_node(new, n, m)
            k = min(k, max(new.nodes[root].width - 1, 0))
            return new.trunc(root, k, role=ir.ROLE_MULT, layer=n.layer,
                             unit=n.unit)

        return rebuild(net, rw)


class SimplifyActs(Pass):
    """Comparator/ReLU simplification: interval-proven ReLU elision
    (exact) + argmax comparator-input truncation (approximate)."""

    name = "simplify-acts"
    monotone_cost = True      # elision removes gates; trunc narrows
    monotone_bound = True     # exact elision / added comparator error

    def __init__(self, argmax_lsb: int = 0):
        self.argmax_lsb = int(argmax_lsb)

    def run(self, net: ir.Netlist) -> ir.Netlist:
        errs = propagate_errors(net)

        def rw(new, old, n, m):
            if n.op == ir.Op.RELU and errs[n.args[0]] == (0, 0):
                a = old.nodes[n.args[0]]
                if a.lo >= 0:                    # provably non-negative
                    return m[n.args[0]]
                if a.hi <= 0:                    # provably non-positive
                    return new.const(0)
                return None
            if n.op == ir.Op.ARGMAX and self.argmax_lsb > 0:
                logits = []
                for a in n.args:
                    na = m[a]
                    k = min(self.argmax_lsb,
                            max(new.nodes[na].width - 1, 0))
                    logits.append(new.trunc(na, k, role=ir.ROLE_ARGMAX)
                                  if k > 0 else na)
                return new.argmax(logits)
            return None

        return rebuild(net, rw)
