"""jit'd wrapper: pads T to the time-block, d to the channel block."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import ssm_scan_pallas
from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.obs import prof as PF
from repro.obs import trace as TR


@functools.partial(jax.jit, static_argnames=("block_d", "block_t",
                                             "interpret"))
def _ssm_scan_jit(u, dt, B_, C_, A, D, *, block_d, block_t,
                  interpret):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    Bsz, T, d = u.shape
    block_d = block_d or min(d, 512)
    padT = (-T) % block_t
    padD = (-d) % block_d
    if padT or padD:
        pt, pd = ((0, 0), (0, padT), (0, padD)), ((0, 0), (0, padT), (0, 0))
        u = jnp.pad(u, pt)
        dt = jnp.pad(dt, pt)
        B_ = jnp.pad(B_, pd)
        C_ = jnp.pad(C_, pd)
        A = jnp.pad(A, ((0, padD), (0, 0)))
        D = jnp.pad(D, ((0, padD),))
    y = ssm_scan_pallas(u, dt, B_, C_, A, D, block_d=block_d,
                        block_t=block_t, interpret=interpret)
    return y[:, :T, :d]


def ssm_scan(u, dt, B_, C_, A, D, *, block_d=None, block_t=8,
             interpret: bool | None = None):
    if not TR.active():
        return _ssm_scan_jit(u, dt, B_, C_, A, D, block_d=block_d,
                             block_t=block_t, interpret=interpret)
    key = ("ssm_scan", u.shape, B_.shape, block_d, block_t)
    with PF.dispatch("kernels.ssm_scan", key,
                     lower=lambda: _ssm_scan_jit.lower(
                         u, dt, B_, C_, A, D, block_d=block_d,
                         block_t=block_t, interpret=interpret),
                     b=u.shape[0], t=u.shape[1], d=u.shape[2]):
        y = _ssm_scan_jit(u, dt, B_, C_, A, D, block_d=block_d,
                          block_t=block_t, interpret=interpret)
        jax.block_until_ready(y)
    return y


__all__ = ["ssm_scan", "ssm_scan_ref"]
