"""Static lint of compression specs (the GA genome) before evaluation.

A `ModelMin` is cheap to build and expensive to evaluate (a QAT finetune +
bespoke compile per spec), and its JSON serialization is a *persistent
cache key* (`batch_eval.EvalCache`). Two classes of silent failure are
worth catching before any training happens:

* **range/arch illegality** — genes outside the lattice the repo's
  semantics support (bits outside [2, 8], more clusters than a layer has
  outputs to cluster, a genome whose layer count does not match the
  dataset's architecture): these either crash mid-finetune or quietly
  degenerate (k-means with k > n points).
* **keyspace instability** — a spec whose serialization does not
  round-trip byte-for-byte (``to_json -> from_json -> to_json``), or that
  smuggles non-canonical scalar types (a ``np.int64`` bits gene) into the
  JSON. Such specs fracture the cache keyspace: the same point evaluates
  twice under two keys, or two different points collide on one.

`lint_spec` returns `Diagnostic` records; `check_specs` raises. The
batched evaluator runs `check_specs` on every population when the ambient
verify flag (`REPRO_VERIFY`) is on.

Run ``python -m repro.verify.spec`` to lint the GA's whole gene lattice
against every printed-MLP dataset (the CI static-analysis gate).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.core.compression_spec import LayerMin, ModelMin
from repro.verify.diagnostics import (ERROR, WARN, Diagnostic,
                                      VerificationError, errors)

# the semantic lattice (mirrors LayerMin.validate / ModelMin.validate, but
# reported instead of asserted, and checked *before* any training)
BITS_RANGE = (2, 8)
SPARSITY_RANGE = (0.0, 0.9)
CLUSTERS_RANGE = (2, 64)
CSD_DROP_RANGE = (0, 8)
LSB_RANGE = (0, 16)
ARGMAX_LSB_RANGE = (0, 16)
INPUT_BITS_RANGE = (1, 16)


def _diag(rule: str, msg: str) -> Diagnostic:
    return Diagnostic(ERROR, rule, msg)


def _check_scalar(out, where: str, name: str, v, lo, hi, *,
                  optional: bool = False, kind=int):
    if v is None:
        if not optional:
            out.append(_diag("range", f"{where}: {name} must be set"))
        return
    if type(v) is not kind and not (kind is float and type(v) is int):
        out.append(_diag(
            "type",
            f"{where}: {name}={v!r} has type {type(v).__name__}, not "
            f"{kind.__name__} — non-canonical scalars serialize "
            "differently and fracture the EvalCache keyspace"))
        return
    if not (lo <= v <= hi):
        out.append(_diag("range",
                         f"{where}: {name}={v} outside [{lo}, {hi}]"))


def lint_spec(spec: ModelMin, cfg=None) -> List[Diagnostic]:
    """Lint one spec. ``cfg`` (a `PrintedMLPConfig`), when given, enables
    the architecture rules (layer count, per-layer cluster capacity)."""
    out: List[Diagnostic] = []
    if not isinstance(spec, ModelMin):
        return [_diag("type", f"not a ModelMin: {type(spec).__name__}")]
    if not spec.layers:
        out.append(_diag("range", "spec has no layers"))
    _check_scalar(out, "model", "input_bits", spec.input_bits,
                  *INPUT_BITS_RANGE)
    _check_scalar(out, "model", "argmax_lsb", spec.argmax_lsb,
                  *ARGMAX_LSB_RANGE)
    for i, l in enumerate(spec.layers):
        w = f"layer[{i}]"
        if not isinstance(l, LayerMin):
            out.append(_diag("type", f"{w}: not a LayerMin: "
                             f"{type(l).__name__}"))
            continue
        _check_scalar(out, w, "bits", l.bits, *BITS_RANGE, optional=True)
        _check_scalar(out, w, "sparsity", l.sparsity, *SPARSITY_RANGE,
                      kind=float)
        _check_scalar(out, w, "clusters", l.clusters, *CLUSTERS_RANGE,
                      optional=True)
        _check_scalar(out, w, "csd_drop", l.csd_drop, *CSD_DROP_RANGE)
        _check_scalar(out, w, "lsb", l.lsb, *LSB_RANGE)

    if cfg is not None and not errors(out):
        dims = cfg.layer_dims
        if len(spec.layers) != len(dims) - 1:
            out.append(_diag(
                "arch",
                f"{len(spec.layers)} layer genes for {cfg.name}'s "
                f"{len(dims) - 1} compressible layers {dims}"))
        else:
            for i, l in enumerate(spec.layers):
                if l.clusters is not None and l.clusters > dims[i + 1]:
                    # degenerate, not illegal: the k-means quietly uses
                    # fewer clusters — the GA's fixed lattice does emit
                    # such genes on small output layers
                    out.append(Diagnostic(
                        WARN, "arch",
                        f"layer[{i}]: {l.clusters} clusters but the layer "
                        f"has only {dims[i + 1]} outputs per input row to "
                        "cluster (k-means degenerates to fewer clusters)"))

    if not errors(out):
        try:
            s1 = spec.to_json()
            s2 = ModelMin.from_json(s1).to_json()
        except (TypeError, ValueError, KeyError) as e:
            out.append(_diag("roundtrip",
                             f"serialization failed: {e!r}"))
        else:
            if s1 != s2:
                out.append(_diag(
                    "roundtrip",
                    "to_json -> from_json -> to_json is not byte-stable "
                    f"({s1!r} vs {s2!r}) — EvalCache keys would drift"))
    return out


def lint_specs(specs: Sequence[ModelMin], cfg=None) -> List[Diagnostic]:
    out: List[Diagnostic] = []
    for i, s in enumerate(specs):
        for d in lint_spec(s, cfg):
            out.append(Diagnostic(d.severity, d.rule,
                                  f"spec[{i}]: {d.message}"))
    return out


def check_specs(specs: Sequence[ModelMin], cfg=None) -> None:
    """Raise `VerificationError` if any spec in the population is illegal
    or keyspace-unstable."""
    bad = errors(lint_specs(specs, cfg))
    if bad:
        raise VerificationError(bad)


def _selftest() -> int:
    """Lint the GA's whole gene lattice against every dataset (CI gate):
    every single-axis choice plus a deterministic random sample of
    combined genomes must lint clean and round-trip byte-stably."""
    import itertools
    import random

    from repro.configs.printed_mlp import PRINTED_MLPS
    from repro.core import ga

    rng = random.Random(0)
    n_err = n_specs = 0
    for cfg in PRINTED_MLPS.values():
        L = len(cfg.layer_dims) - 1
        single = [ModelMin.uniform(L, csd_drop=c, lsb=t, argmax_lsb=a)
                  for c, t, a in itertools.product(
                      ga.CSD_DROP_CHOICES, ga.LSB_CHOICES,
                      ga.ARGMAX_LSB_CHOICES)]
        for axis, choices in (("bits", ga.BITS_CHOICES),
                              ("sparsity", ga.SPARSITY_CHOICES),
                              ("clusters", ga.CLUSTER_CHOICES)):
            single += [ModelMin.uniform(L, **{axis: c}) for c in choices]
        combos = [ModelMin(tuple(LayerMin(rng.choice(ga.BITS_CHOICES),
                                          rng.choice(ga.SPARSITY_CHOICES),
                                          rng.choice(ga.CLUSTER_CHOICES),
                                          rng.choice(ga.CSD_DROP_CHOICES),
                                          rng.choice(ga.LSB_CHOICES))
                                 for _ in range(L)),
                           8, rng.choice(ga.ARGMAX_LSB_CHOICES))
                  for _ in range(200)]
        for s in single + combos:
            n_specs += 1
            for d in lint_spec(s, cfg):
                n_err += d.severity == ERROR
                if d.severity == ERROR:
                    print(f"{cfg.name}: {d}")
    print(f"spec lint: {n_specs} specs over {len(PRINTED_MLPS)} datasets, "
          f"{n_err} error(s)")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(_selftest())
