"""Per-architecture smoke tests (deliverable f).

Every assigned arch instantiates a REDUCED same-family config and runs:
  * one forward pass — shape + finiteness asserts
  * one train step (loss + grads) — finiteness + loss decreases over 3 steps
  * one-token decode — shape + cache-length bookkeeping
  * decode-vs-forward logits consistency (the strongest invariant: the
    cached/absorbed/ring decode paths must agree with the full forward)

Full configs are exercised via jax.eval_shape param counting — validates the
configs reproduce the published parameter counts without allocating.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ARCH_IDS
from repro.nn import transformer as T

ALL = sorted(ARCH_IDS)


def _batch(key, cfg, B, S):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.encoder is not None:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder.num_frames, cfg.d_model), jnp.float32) * 0.1
    if cfg.vision is not None:
        batch["patches"] = jax.random.normal(
            key, (B, cfg.vision.num_patches, cfg.d_model), jnp.float32) * 0.1
    return batch


def _no_drop(cfg):
    """Raise MoE capacity so the decode-vs-forward test has no dropped tokens."""
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))


@pytest.mark.parametrize("name", ALL)
def test_forward_smoke(name, key):
    cfg = ARCHS[name].reduced()
    params = T.init(key, cfg)
    B, S = 2, 8
    batch = _batch(key, cfg, B, S)
    logits, aux = T.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ALL)
def test_train_step_smoke(name, key):
    cfg = ARCHS[name].reduced()
    params = T.init(key, cfg)
    B, S = 2, 8
    batch = _batch(key, cfg, B, S)
    labels = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)

    def loss_fn(p):
        logits, aux = T.forward(p, batch, cfg)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], -1).mean()
        return nll + 0.01 * aux

    losses = []
    for _ in range(3):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        assert bool(jnp.isfinite(loss))
        for g in jax.tree_util.tree_leaves(grads):
            assert bool(jnp.all(jnp.isfinite(g)))
        params = jax.tree_util.tree_map(
            lambda p, g: p - 0.05 * g.astype(p.dtype), params, grads)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("name", ALL)
def test_decode_matches_forward(name, key):
    cfg = _no_drop(ARCHS[name].reduced())
    params = T.init(key, cfg)
    B, S = 2, 6
    batch = _batch(key, cfg, B, S)
    full_logits, _ = T.forward(params, batch, cfg, remat=False)

    state = T.init_decode_state(cfg, B, 16, jnp.float32)
    if cfg.encoder is not None:
        state["enc_out"] = T._encoder_forward(
            params["encoder"], batch["frames"], cfg, remat=False)
    elif cfg.vision is not None:
        state["enc_out"] = batch["patches"]
    outs = []
    for t in range(S):
        logits, state = T.decode_step(params, state,
                                      batch["tokens"][:, t:t + 1], cfg)
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


# --- full-config fidelity (no allocation) ----------------------------------

EXPECTED_PARAMS_B = {
    "nemotron-4-340b": (320, 360),
    "qwen3-0.6b": (0.4, 0.8),
    "gemma-7b": (7.5, 9.5),
    "gemma2-2b": (2.2, 3.2),
    "recurrentgemma-9b": (8.0, 10.5),
    "whisper-base": (0.05, 0.12),     # +32k-pos table for backbone shapes
    "falcon-mamba-7b": (6.5, 8.0),
    "llama-3.2-vision-11b": (9.5, 11.5),
    "deepseek-v2-236b": (225, 248),
    "phi3.5-moe-42b-a6.6b": (39, 45),
}


@pytest.mark.parametrize("name", ALL)
def test_full_config_param_count(name, key):
    cfg = ARCHS[name]
    shapes = jax.eval_shape(lambda k: T.init(k, cfg), key)
    n = sum(int(np.prod(s.shape)) for s in jax.tree_util.tree_leaves(shapes))
    lo, hi = EXPECTED_PARAMS_B[name]
    assert lo * 1e9 <= n <= hi * 1e9, f"{name}: {n/1e9:.2f}B not in [{lo},{hi}]"


def test_moe_active_params():
    cfg = ARCHS["phi3.5-moe-42b-a6.6b"]
    key = jax.random.PRNGKey(0)
    shapes = jax.eval_shape(lambda k: T.init(k, cfg), key)
    n = T.active_param_count(shapes, cfg)
    assert 5.5e9 <= n <= 7.5e9, f"active {n/1e9:.2f}B"
