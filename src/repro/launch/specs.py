"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

`input_specs` returns abstract model inputs (weak-type-correct, shardable, no
device allocation); `abstract_state` / `abstract_decode_state` eval_shape the
train/serve state. The dry-run lowers against these.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.dist import sharding as SH
from repro.nn import transformer as T
from repro.train.optimizer import AdamWConfig
from repro.train import train_state as TS


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_divisor(mesh: Mesh) -> int:
    return int(jax.numpy.prod(jnp.asarray(
        [mesh.shape[a] for a in SH.batch_axes(mesh)])))


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract model inputs for the cell (training batch or decode tokens)."""
    B = shape.global_batch
    if shape.kind == "decode":
        specs = {"tokens": _sds((B, 1), jnp.int32)}
    else:
        specs = {"tokens": _sds((B, shape.seq_len), jnp.int32)}
    if cfg.encoder is not None and shape.kind != "decode":
        specs["frames"] = _sds((B, cfg.encoder.num_frames, cfg.d_model),
                               jnp.dtype(cfg.dtype))
    if cfg.vision is not None and shape.kind != "decode":
        specs["patches"] = _sds((B, cfg.vision.num_patches, cfg.d_model),
                                jnp.dtype(cfg.dtype))
    return specs


def input_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    specs = input_specs(cfg, shape)
    div = 1
    for a in SH.batch_axes(mesh):
        div *= mesh.shape[a]
    baxes = SH.batch_axes(mesh) if shape.global_batch % max(div, 1) == 0 \
        else ()

    def spec(path, leaf):
        axes: list = [None] * len(leaf.shape)
        if baxes:
            axes[0] = baxes
        return NamedSharding(mesh, P(*axes))

    return jax.tree_util.tree_map_with_path(spec, specs)


def abstract_train_state(cfg: ArchConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    opt_cfg = AdamWConfig()
    return jax.eval_shape(lambda k: TS.init_state(k, cfg, opt_cfg), key)


def train_state_shardings(cfg: ArchConfig, mesh: Mesh, state_shapes=None):
    state_shapes = state_shapes if state_shapes is not None \
        else abstract_train_state(cfg)
    pspecs = SH.param_specs(state_shapes.params, mesh)
    pshard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)
    mshard = pshard
    return TS.TrainState(
        params=pshard,
        opt=state_shapes.opt._replace(
            step=NamedSharding(mesh, P()),
            m=mshard, v=jax.tree_util.tree_map(lambda x: x, mshard)),
    )


def abstract_decode_state(cfg: ArchConfig, shape: ShapeConfig, kv_dtype=None):
    B = shape.global_batch
    dtype = jnp.dtype(kv_dtype) if kv_dtype else jnp.dtype(cfg.dtype)
    return jax.eval_shape(
        lambda: T.init_decode_state(cfg, B, shape.seq_len, dtype))


def decode_state_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                           state_shapes=None):
    state_shapes = state_shapes if state_shapes is not None \
        else abstract_decode_state(cfg, shape)
    div = 1
    for a in SH.batch_axes(mesh):
        div *= mesh.shape[a]
    # batch too small to shard (long_500k B=1): replicate the batch dim
    ok = shape.global_batch % max(div, 1) == 0
    specs = SH.cache_specs(state_shapes, mesh, cfg, shard_batch=ok)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def abstract_params(cfg: ArchConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: T.init(k, cfg), key)


def param_shardings(cfg: ArchConfig, mesh: Mesh, params_shapes=None):
    params_shapes = params_shapes if params_shapes is not None \
        else abstract_params(cfg)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        SH.param_specs(params_shapes, mesh))
