"""Training loop with fault tolerance: periodic async checkpoints, resume,
deterministic data, preemption hook, straggler deadline (documented no-op on
single host — the code path is exercised in tests via the barrier timeout).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.train import train_state as TS
from repro.train.optimizer import AdamWConfig


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    microbatch: Optional[int] = None
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    straggler_deadline_s: float = 0.0   # >0: skip-slow-batch barrier (docs §7)


class Trainer:
    def __init__(self, cfg: ArchConfig, opt_cfg: AdamWConfig,
                 tcfg: TrainerConfig, pipeline: TokenPipeline, *,
                 extra_batch: Optional[Callable[[int], Dict]] = None):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.pipeline = pipeline
        self.extra_batch = extra_batch
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)
                     if tcfg.ckpt_dir else None)
        self._preempted = False
        self.step_fn = jax.jit(TS.make_train_step(
            cfg, opt_cfg, remat=True, microbatch=tcfg.microbatch))
        self.history: List[Dict] = []

    # -- fault tolerance hooks ----------------------------------------------

    def request_preemption(self, *_):
        """SIGTERM handler at scale: finish the step, checkpoint, exit."""
        self._preempted = True

    def install_signal_handler(self):
        signal.signal(signal.SIGTERM, self.request_preemption)

    # -- main loop ------------------------------------------------------------

    def init_or_resume(self, key) -> tuple:
        state = TS.init_state(key, self.cfg, self.opt_cfg)
        start_step = 0
        if self.ckpt is not None:
            restored, meta = self.ckpt.restore(like=state)
            if restored is not None:
                state = restored
                start_step = int(meta["step"]) + 1
        return state, start_step

    def run(self, key=None) -> Dict:
        key = key if key is not None else jax.random.PRNGKey(0)
        state, start = self.init_or_resume(key)
        t_start = time.time()
        for step in range(start, self.tcfg.total_steps):
            batch_np = self.pipeline.batch_at(step)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            if self.extra_batch is not None:
                batch.update(self.extra_batch(step))
            t0 = time.time()
            state, metrics = self.step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if step % self.tcfg.log_every == 0 or \
                    step == self.tcfg.total_steps - 1:
                rec = {"step": step, "loss": loss,
                       "lr": float(metrics["lr"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "step_s": round(dt, 4)}
                self.history.append(rec)
                print(f"step {step:6d} loss {loss:8.4f} "
                      f"gnorm {rec['grad_norm']:7.3f} {dt*1e3:7.1f} ms",
                      flush=True)
            if self.ckpt is not None and (
                    step % self.tcfg.ckpt_every == 0 and step > 0
                    or self._preempted
                    or step == self.tcfg.total_steps - 1):
                self.ckpt.save(step, state, meta={"step": step, "loss": loss})
            if self._preempted:
                break
        if self.ckpt is not None:
            self.ckpt.wait()
        return {"history": self.history,
                "final_loss": self.history[-1]["loss"] if self.history else None,
                "wall_s": time.time() - t_start,
                "preempted": self._preempted,
                "last_step": step if self.tcfg.total_steps else -1}
