"""Architecture / shape configuration dataclasses.

Every assigned architecture is described by an :class:`ArchConfig`. Models are
assembled from *segments*: a segment is a repeating pattern of
:class:`LayerSpec` entries. The repeat dimension is executed with
``jax.lax.scan`` over stacked parameters so the HLO size stays O(pattern), not
O(depth) — required to dry-run 96-layer models on this container.

Shapes (the four assigned input-shape cells) are :class:`ShapeConfig`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------

MIXERS = ("attn", "local", "rec", "ssm", "cross")
FFNS = ("dense", "moe", "none")


@dataclass(frozen=True)
class LayerSpec:
    """One transformer block position inside a repeating pattern.

    mixer: "attn" global self-attention | "local" sliding-window attention |
           "rec" RG-LRU recurrent block | "ssm" Mamba-1 block |
           "cross" self-attention followed by cross-attention (enc-dec / VLM)
    ffn:   "dense" | "moe" | "none" (mamba blocks carry their own channel mix)
    """

    mixer: str = "attn"
    ffn: str = "dense"

    def __post_init__(self):
        assert self.mixer in MIXERS, self.mixer
        assert self.ffn in FFNS, self.ffn


@dataclass(frozen=True)
class Segment:
    """``pattern`` repeated ``repeats`` times (scanned over ``repeats``)."""

    pattern: Tuple[LayerSpec, ...]
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeats


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                      # per-expert FFN hidden dim
    num_shared_experts: int = 0
    d_shared: int = 0                  # hidden dim of the shared expert FFN
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    router_softmax: bool = True        # False -> sigmoid scoring (deepseek-v3 style)
    dispatch: str = "global"           # "global" | "per_sample" (EP-local
    # routing: sort/gather stay inside the batch shard; see §Perf)


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 block."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                   # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class RGLRUConfig:
    """RG-LRU recurrent block (Griffin / RecurrentGemma)."""

    lru_width: int = 0                 # 0 -> d_model
    d_conv: int = 4
    c_exponent: float = 8.0


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style audio encoder backbone (conv frontend is a stub:
    input_specs() feeds precomputed frame embeddings)."""

    num_layers: int
    num_frames: int = 1500             # 30s audio at 50 Hz after conv stack
    d_frontend: int = 0                # 0 -> d_model (stub embeddings arrive at d_model)


@dataclass(frozen=True)
class VisionConfig:
    """Llama-3.2-Vision style cross-attention to stub patch embeddings."""

    num_patches: int = 1601            # 448x448 @ patch 14 (+cls), 4 tiles collapsed
    d_patch: int = 0                   # 0 -> d_model (stub embeddings arrive at d_model)


# ---------------------------------------------------------------------------
# ArchConfig
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | audio | vlm
    d_model: int
    vocab_size: int
    segments: Tuple[Segment, ...]
    # attention
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    window_size: int = 0               # sliding window for "local" mixers
    qk_norm: bool = False
    attn_softcap: float = 0.0          # gemma2 attention logit soft-capping
    logit_softcap: float = 0.0         # gemma2 final logit soft-capping
    rope_theta: float = 10000.0
    use_rope: bool = True
    max_position_embeddings: int = 0   # >0 -> learned absolute positions (whisper)
    # ffn
    d_ff: int = 0
    mlp_type: str = "swiglu"           # swiglu | geglu | relu2 | gelu
    # norm
    norm_type: str = "rmsnorm"         # rmsnorm | layernorm
    norm_unit_offset: bool = False     # gemma-style (1 + w) RMSNorm scale
    post_norm: bool = False            # gemma2-style post-sublayer norms
    embed_scale: bool = False          # gemma-style sqrt(d_model) embedding scale
    tie_embeddings: bool = False
    # sub-modules
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionConfig] = None
    # numerics
    dtype: str = "bfloat16"
    attn_lowp_probs: bool = False      # bf16 attention scores/probs (perf
    # policy; halves the dominant HBM term of attention-heavy cells)
    remat_policy: str = "nothing"      # "nothing" | "dots" (save matmul outs)
    # provenance
    source: str = ""
    notes: str = ""

    # -- derived -----------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return sum(s.num_layers for s in self.segments)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def sub_quadratic(self) -> bool:
        """True when no segment contains a *global* attention mixer, i.e. the
        architecture can decode at 500k context with O(window)/O(1) state."""
        for seg in self.segments:
            for spec in seg.pattern:
                if spec.mixer in ("attn", "cross"):
                    return False
        return True

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        out = []
        for seg in self.segments:
            out.extend(seg.pattern * seg.repeats)
        return tuple(out)

    def reduced(self, **overrides) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        small: dict = dict(
            d_model=64,
            vocab_size=256,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=16 if self.num_heads else 0,
            d_ff=128 if self.d_ff else 0,
            window_size=min(self.window_size, 16) if self.window_size else 0,
            max_position_embeddings=128 if self.max_position_embeddings else 0,
            dtype="float32",
        )
        # shrink segments: keep the pattern, cut repeats
        segs = tuple(
            Segment(s.pattern, min(s.repeats, 2)) for s in self.segments
        )
        small["segments"] = segs
        if self.moe:
            small["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, d_expert=64,
                d_shared=64 if self.moe.num_shared_experts else 0)
        if self.mla:
            small["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=32,
                                     qk_nope_head_dim=16, qk_rope_head_dim=8,
                                     v_head_dim=16)
        if self.ssm:
            small["ssm"] = dataclasses.replace(self.ssm, d_state=4, dt_rank=8)
        if self.rglru:
            small["rglru"] = dataclasses.replace(self.rglru, lru_width=64)
        if self.encoder:
            small["encoder"] = dataclasses.replace(
                self.encoder, num_layers=2, num_frames=16)
        if self.vision:
            small["vision"] = dataclasses.replace(self.vision, num_patches=16)
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# ShapeConfig — the assigned input-shape cells
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Cell skip policy (documented in DESIGN.md §9)."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, ("full/global attention at 524k context is the "
                       "quadratic-regime artifact the shape excludes; "
                       "run only for SSM/hybrid archs")
    return True, ""
