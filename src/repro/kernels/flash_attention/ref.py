"""Pure-jnp oracle for flash_attention."""
import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q: (BH, T, d); k/v: (BH, S, d)."""
    T, S = q.shape[1], k.shape[1]
    d = q.shape[-1]
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qp = jnp.arange(T)[:, None]
    kp = jnp.arange(S)[None, :]
    ok = jnp.ones((T, S), bool)
    if causal:
        ok &= kp <= qp
    if window:
        ok &= kp > qp - window
    s = jnp.where(ok[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(ok[None], p, 0.0)
    return jnp.einsum("bts,bsd->btd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
