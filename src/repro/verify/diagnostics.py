"""Structured diagnostics shared by every analyzer in `repro.verify`.

A verifier never asserts: it returns :class:`Diagnostic` records —
machine-readable (rule id, severity, node id) and human-readable (message +
node provenance) at once — so callers can decide whether a finding is fatal
(``check_netlist`` raises), a report line (the CI gate), or a statistic
(the mutation-catalog tests count catches per rule).

Severities
----------
``ERROR``  a structural-soundness violation: the netlist (or spec) is not a
           well-formed object of its domain — wrong interval, dangling
           argument, broken bookkeeping. Always fatal under ``check_*``.
``WARN``   a microarchitectural-convention violation: the object is
           structurally sound but does not look like anything the compiler
           or the sanctioned passes emit (a TRUNC outside the approximation
           sites, a non-canonical shared constant). Fatal only under
           ``strict`` checking — hand-built test netlists stay legal.

The ambient switch: `verify_enabled` reads ``REPRO_VERIFY`` (the test
suite turns it on in ``tests/conftest.py``; production paths leave it off
and pay nothing).
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Sequence

ERROR = "error"
WARN = "warn"

ENV_FLAG = "REPRO_VERIFY"


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding of one analyzer rule."""
    severity: str                    # ERROR | WARN
    rule: str                        # stable rule id, e.g. "interval"
    message: str
    node: Optional[int] = None       # offending node id (netlist rules)
    provenance: str = ""             # op/role/layer/unit of that node

    def __str__(self) -> str:
        where = f" @node {self.node}" if self.node is not None else ""
        prov = f" [{self.provenance}]" if self.provenance else ""
        return f"{self.severity}:{self.rule}{where}{prov}: {self.message}"


class VerificationError(AssertionError):
    """Raised by ``check_*`` helpers when diagnostics are fatal. Subclasses
    AssertionError so legacy callers treating `Netlist.validate()` as an
    assertion boundary keep working."""

    def __init__(self, diags: Sequence[Diagnostic]):
        self.diagnostics = list(diags)
        lines = "\n".join(f"  {d}" for d in self.diagnostics)
        super().__init__(
            f"{len(self.diagnostics)} verification finding(s):\n{lines}")


def errors(diags: Sequence[Diagnostic]) -> List[Diagnostic]:
    return [d for d in diags if d.severity == ERROR]


def verify_enabled(override: Optional[bool] = None) -> bool:
    """Resolve the ambient verification switch: an explicit argument wins,
    else the ``REPRO_VERIFY`` env var (off unless set truthy)."""
    if override is not None:
        return bool(override)
    return os.environ.get(ENV_FLAG, "0").lower() not in ("", "0", "false",
                                                         "off")
