"""Minimal functional neural-net substrate (no flax in container).

Every layer is an ``init_*(key, ...) -> params`` / ``apply(params, x, ...)``
pair over plain dict pytrees. Parameter leaf names are stable and are used by
``repro.dist.sharding`` (partition rules) and ``repro.core`` (compression
specs) — do not rename leaves casually.
"""
from repro.nn import layers, attention, moe, ssm, rglru, transformer  # noqa: F401
