"""Deterministic fault injection for the search runtime.

Nothing here touches wall-clock, signals or threads: every fault is
scheduled by (round, island) coordinates or by target spec, so a faulty
run is exactly reproducible and the recovery invariants — zero completed
evaluations lost, bit-identical resume — can be asserted, not eyeballed.

Fault classes covered (`tests/test_search_faults.py`):

* **straggle** — synthetic arrival times past the fleet deadline: the
  island is ejected for the round by `deadline_barrier`, its offspring
  budget redistributed.
* **kill_island** — :class:`IslandKilled` raised mid-generation (after the
  island committed its evaluations to the shared memo): permanent death,
  pure-function rollback.
* **eval faults** — exceptions raised from inside
  `batch_eval._compile_and_price`'s per-candidate attempt loop via the
  module's fault hook: one failing attempt exercises the retry, two the
  quarantine.
* **preempt_at** — the runtime flushes a checkpoint and raises
  `PreemptedError` after the given round, simulating a SIGTERM'd worker.
* **tear_cache_at** — the on-disk `EvalCache` JSON is truncated before the
  given round, simulating a crash mid-write; `EvalCache._read` salvages.
"""
from __future__ import annotations

import contextlib
import dataclasses
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import batch_eval as BE
from repro.search.islands import IslandKilled


# ---------------------------------------------------------------------------
# evaluation-exception injection (hooks into batch_eval's attempt loop)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EvalFault:
    """Raise ``make_error()`` from inside candidate evaluation.

    ``spec_json`` — target spec (None = every spec); ``fail_attempts`` —
    how many attempts fail: 1 models a transient fault (the built-in retry
    absorbs it), >=2 a deterministic one (the spec quarantines).
    """
    spec_json: Optional[str] = None
    make_error: Callable[[], BaseException] = \
        lambda: OverflowError("injected: netlist sim budget exceeded")
    fail_attempts: int = 1


class _EvalFaultHook:
    def __init__(self, faults: List[EvalFault]):
        self.faults = list(faults)
        self.triggered: List[Tuple[str, int]] = []

    def __call__(self, spec, attempt: int) -> None:
        sj = spec.to_json()
        for f in self.faults:
            if f.spec_json is not None and sj != f.spec_json:
                continue
            if attempt <= f.fail_attempts:
                self.triggered.append((sj, attempt))
                raise f.make_error()


@contextlib.contextmanager
def inject_eval_faults(faults: List[EvalFault]):
    """Context manager installing the faults into `batch_eval`'s hook;
    yields the hook (``.triggered`` records every injected raise)."""
    hook = _EvalFaultHook(faults)
    prev = BE.set_eval_fault_hook(hook)
    try:
        yield hook
    finally:
        BE.set_eval_fault_hook(prev)


# ---------------------------------------------------------------------------
# fleet-level fault schedule
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FaultPlan:
    # (round, island) -> synthetic arrival seconds (vs the fleet deadline)
    straggle: Dict[Tuple[int, int], float] = dataclasses.field(
        default_factory=dict)
    # island -> first round in which its worker dies mid-generation
    kill_island: Dict[int, int] = dataclasses.field(default_factory=dict)
    # request preemption after this round completes (checkpoint + raise)
    preempt_at: Optional[int] = None
    # truncate the EvalCache file just before this round starts
    tear_cache_at: Optional[int] = None
    tear_fraction: float = 0.5        # bytes kept


class FaultHarness:
    """The runtime-facing adapter for a :class:`FaultPlan`. Implements the
    duck-typed harness surface of `SearchRuntime` (arrival times, kill
    hook, preemption flag, before-round actions) and logs everything it
    injects."""

    def __init__(self, plan: FaultPlan, *, cache_path=None):
        self.plan = plan
        self.cache_path = Path(cache_path) if cache_path is not None else None
        self.log: List[Tuple] = []

    def arrival_time(self, island: int, round_idx: int) -> float:
        return float(self.plan.straggle.get((round_idx, island), 0.0))

    def island_kill_hook(self, island: int, round_idx: int) -> None:
        kill_round = self.plan.kill_island.get(island)
        if kill_round is not None and round_idx >= kill_round:
            self.log.append(("kill", island, round_idx))
            raise IslandKilled(
                f"fault harness: island {island} worker died "
                f"mid-generation in round {round_idx}")

    def preemption_requested(self, round_idx: int) -> bool:
        return (self.plan.preempt_at is not None
                and round_idx >= self.plan.preempt_at)

    def before_round(self, round_idx: int, runtime) -> None:
        if (self.plan.tear_cache_at == round_idx
                and self.cache_path is not None
                and self.cache_path.exists()):
            data = self.cache_path.read_bytes()
            keep = int(len(data) * self.plan.tear_fraction)
            self.cache_path.write_bytes(data[:keep])
            self.log.append(("tear_cache", round_idx, len(data), keep))


__all__ = ["EvalFault", "FaultHarness", "FaultPlan", "inject_eval_faults"]
