"""Three-term roofline from compiled dry-run artifacts.

  compute    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = collective_bytes_per_chip / ICI_link_bw

Two measurement caveats handled here (verified empirically on this jax/XLA):

1. ``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of
   trip count. Depth therefore cannot be read off the full (scanned) compile.
   The dry-run lowers *unrolled depth-reduced* variants of each cell and fits
   the affine model  cost(R) = base + sum_i R_i * body_i  (R = segment repeat
   counts); the full-depth cost is then base + sum_i R_full_i * body_i.
   Collective bytes are extrapolated the same way.

2. Collective bytes are not in cost_analysis: we parse the post-SPMD HLO for
   all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
   (including async -start forms) and sum result-shape bytes with standard
   ring-algorithm wire factors.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.roofline.hw import HWSpec, TPU_V5E

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

# `%x = bf16[8,128,256]{...} all-gather(...)` / `all-reduce-start(...)`
_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^\s]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")

# wire traffic per device as a multiple of the RESULT bytes (ring algorithms)
_WIRE_FACTOR = {
    "all-gather": 1.0,          # receives (n-1)/n of the result ~ result
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "reduce-scatter": 1.0,      # sends operand, result is the shard: operand ~ n*result
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-op-kind wire bytes per device, parsed from post-SPMD HLO."""
    out: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, kind, _ = m.groups()
        size = _DTYPE_BYTES.get(dt, 4)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out[kind] = out.get(kind, 0.0) + n * size * _WIRE_FACTOR[kind]
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def cost_dict(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def memory_dict(compiled) -> Dict[str, float]:
    ms = compiled.memory_analysis()
    return {
        "argument_bytes": float(ms.argument_size_in_bytes),
        "output_bytes": float(ms.output_size_in_bytes),
        "temp_bytes": float(ms.temp_size_in_bytes),
        "alias_bytes": float(ms.alias_size_in_bytes),
        "code_bytes": float(ms.generated_code_size_in_bytes),
    }


# ---------------------------------------------------------------------------
# affine depth extrapolation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DepthFit:
    """cost(R) = base + sum_i R_i * body_i, one entry per depth knob."""
    base: Dict[str, float]
    bodies: List[Dict[str, float]]

    def at(self, repeats: Sequence[int]) -> Dict[str, float]:
        assert len(repeats) == len(self.bodies)
        out = dict(self.base)
        for r, b in zip(repeats, self.bodies):
            for k, v in b.items():
                out[k] = out.get(k, 0.0) + r * v
        return out


def fit_depth(measure, n_knobs: int) -> DepthFit:
    """measure(repeats_tuple) -> dict of costs; lowers n_knobs+1 variants:
    all-ones and ones+e_i."""
    ones = tuple([1] * n_knobs)
    f0 = measure(ones)
    bodies = []
    for i in range(n_knobs):
        r = list(ones)
        r[i] += 1
        fi = measure(tuple(r))
        bodies.append({k: fi.get(k, 0.0) - f0.get(k, 0.0) for k in f0})
    base = {k: f0[k] - sum(b.get(k, 0.0) for b in bodies) for k in f0}
    return DepthFit(base=base, bodies=bodies)


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    hw: HWSpec = TPU_V5E

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / self.hw.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_step(self) -> float:
        """Ideal-overlap step time: max of the three engines."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def t_serial(self) -> float:
        return self.t_compute + self.t_memory + self.t_collective

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "t_step_s": self.t_step,
            "dominant": self.dominant,
        }


def model_flops(n_active_params: int, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference forward)."""
    per_tok = 6.0 if kind == "train" else 2.0
    return per_tok * n_active_params * tokens
