"""TPU roofline cost model for compression specs (beyond-paper adaptation).

The paper's GA prices candidates with the *printed circuit* area model. On
TPU the deployment cost of a weight pytree under a compression spec is the
roofline time of the serving step, dominated at decode by HBM weight traffic:

  bytes(layer) =  dense:      K*N*2                      (bf16)
                  quantized:  K*N*bits/8 + scales
                  clustered:  K*N*ceil(log2(k))/8 + codebooks
                  pruned(block): surviving_tiles/total * above

  t_mem = bytes/HBM_bw ;  t_compute = flops/peak  ;  cost = max(...)

This is the objective `core.ga` minimizes for LM specs; accuracy is proxied
by the spec's aggregate reconstruction error (cheap) or measured by eval
loss (exact) depending on the caller's budget.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import jax
import numpy as np

from repro.core.compression_spec import LayerMin, ModelMin
from repro.roofline.hw import TPU_V5E


@dataclasses.dataclass(frozen=True)
class LayerShape:
    K: int
    N: int


def layer_weight_bytes(shape: LayerShape, lm: LayerMin) -> float:
    """HBM bytes to stream one weight matrix under the spec."""
    n_weights = shape.K * shape.N
    keep = 1.0 - lm.sparsity            # block-sparse tiles skipped
    if lm.clusters is not None:
        idx_bits = max(math.ceil(math.log2(lm.clusters)), 1)
        codebook = shape.K * lm.clusters * 2          # per-row fp16 codebooks
        return keep * n_weights * idx_bits / 8.0 + codebook
    if lm.bits is not None:
        scales = shape.N * 2
        return keep * n_weights * lm.bits / 8.0 + scales
    return keep * n_weights * 2.0


def spec_cost_seconds(shapes, spec: ModelMin, *, batch_tokens: int = 1,
                      hw=TPU_V5E, chips: int = 1) -> Dict[str, float]:
    """Decode-step roofline for a stack of layers under a spec.

    shapes: list[LayerShape] (one per spec layer). Returns the three terms
    and the max (the cost the GA minimizes)."""
    assert len(shapes) == len(spec.layers)
    total_bytes = sum(layer_weight_bytes(s, lm)
                      for s, lm in zip(shapes, spec.layers))
    total_flops = sum(2.0 * s.K * s.N * batch_tokens * (1.0 - lm.sparsity)
                      for s, lm in zip(shapes, spec.layers))
    t_mem = total_bytes / (chips * hw.hbm_bw)
    t_comp = total_flops / (chips * hw.peak_flops)
    return {"t_mem": t_mem, "t_comp": t_comp,
            "cost": max(t_mem, t_comp), "bytes": total_bytes,
            "flops": total_flops}


def lm_layer_shapes(params) -> Dict[str, LayerShape]:
    """Extract 2D+ matmul weight shapes from an LM param pytree, keyed by
    path — the compressible layer inventory for the GA."""
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        if hasattr(leaf, "shape") and len(leaf.shape) >= 2 \
                and leaf.shape[-1] >= 64 and leaf.shape[-2] >= 64:
            name = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                            for k in path)
            K = int(np.prod(leaf.shape[:-1]))
            out[name] = LayerShape(K=K, N=int(leaf.shape[-1]))
    return out
