from repro.kernels.block_sparse_matmul.ops import (block_sparse_matmul,
                                                   block_sparse_matmul_ref)

__all__ = ["block_sparse_matmul", "block_sparse_matmul_ref"]
