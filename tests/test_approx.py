"""Approximation subsystem tests.

The two acceptance invariants of the subsystem:

* **Identity** — the PassManager with no passes (or all-zero knobs) yields
  a netlist whose simulation is bit-exact against
  `minimize.integer_forward` and whose structural cost equals
  `hw_model.mlp_cost` exactly — the PR 3 invariants survive the rebuild
  machinery.
* **Soundness** — for every pass (alone and composed), the measured max
  logit error on real inputs never exceeds the interval analyzer's
  predicted bound: across all four UCI datasets and a randomized spec
  sweep.
"""
import numpy as np
import pytest

from repro import approx, circuit
from repro.circuit import ir
from repro.configs.printed_mlp import PRINTED_MLPS
from repro.core import hw_model as HW
from repro.core import minimize as MZ
from repro.core.compression_spec import LayerMin, ModelMin

from test_circuit import (assert_bit_exact, assert_cost_matches,
                          synth_compiled)

RNG = np.random.default_rng(7)


def _measured_ok(anet, compiled, x):
    bound = approx.logit_error_bound(anet)
    measured = approx.measured_max_logit_error(anet, compiled, x)
    assert measured <= bound, (measured, bound)
    return measured, bound


# ---------------------------------------------------------------------------
# identity: the rebuild machinery preserves the PR 3 invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dims,bits,sparsity,clusters", [
    ((7, 8, 3), 8, 0.0, None),
    ((11, 10, 7), 6, 0.5, None),
    ((16, 20, 10), 8, 0.3, 8),
    ((5, 6, 6, 4), 7, 0.2, 3),
])
def test_empty_passmanager_is_identity(dims, bits, sparsity, clusters):
    c = synth_compiled(dims, bits, sparsity=sparsity, clusters=clusters,
                       seed=11)
    net = circuit.compile_netlist(c)
    out = approx.PassManager([]).run(net)
    x = RNG.random((13, dims[0])).astype(np.float32)
    assert_bit_exact(out, c, x)
    assert_cost_matches(out, c)
    assert len(out) == len(net)
    assert out.critical_path_levels() == net.critical_path_levels()


def test_all_zero_knobs_are_identity():
    c = synth_compiled((9, 8, 4), 5, sparsity=0.2, clusters=4, seed=3)
    net = circuit.compile_netlist(c)
    p = approx.ApproxParams.zero(net.n_layers)
    assert p.is_identity
    out = approx.approximate(net, p)
    assert_bit_exact(out, c, RNG.random((9, 9)).astype(np.float32))
    assert_cost_matches(out, c)
    assert approx.logit_error_bound(out) == 0
    assert approx.decision_error_bound(out) == 0


def test_zero_gene_spec_json_is_byte_stable():
    """Exact specs keep their historical JSON (EvalCache keys embed it)."""
    s = ModelMin.uniform(2, bits=4, sparsity=0.3, clusters=8)
    assert not s.has_approx
    assert s.to_json() == (
        '{"input_bits": 8, "layers": ['
        '{"bits": 4, "sparsity": 0.3, "clusters": 8}, '
        '{"bits": 4, "sparsity": 0.3, "clusters": 8}]}')
    ax = ModelMin.uniform(2, bits=4, csd_drop=1, lsb=2, argmax_lsb=3)
    assert ax.has_approx
    assert ModelMin.from_json(ax.to_json()) == ax
    assert ModelMin.from_json(s.to_json()) == s


# ---------------------------------------------------------------------------
# the TRUNC op
# ---------------------------------------------------------------------------


def test_trunc_ir_semantics_and_zero_shift():
    net = ir.Netlist(in_bits=8, w_bits=[8])
    x = net.input(0)
    t = net.trunc(x, 3)
    assert net.nodes[t].op == ir.Op.TRUNC
    assert (net.nodes[t].lo, net.nodes[t].hi) == (0, (255 >> 3) << 3)
    assert net.trunc(x, 0) == x           # identity emits no node
    n = net.neg(x)                        # [-255, 0]
    tn = net.trunc(n, 3)
    assert (net.nodes[tn].lo, net.nodes[tn].hi) == ((-255 >> 3) << 3, 0)
    # TRUNC is a wire in the delay model
    assert net.depths()[t] == net.depths()[x]


def test_trunc_simulation_floors_toward_minus_inf():
    net = ir.Netlist(in_bits=4, w_bits=[4])
    x = net.input(0)
    m = net.sub(net.const(0), x)           # -x in [-15, 0]
    net.layer_pre_ids.append([net.trunc(m, 2), net.trunc(x, 2)])
    net.output_ids = list(net.layer_pre_ids[-1])
    net.argmax(net.output_ids)
    net.validate()
    out = circuit.simulate(net, np.arange(16)[:, None])
    vals = np.arange(16)
    np.testing.assert_array_equal(out["pre"][0][:, 0], (-vals >> 2) << 2)
    np.testing.assert_array_equal(out["pre"][0][:, 1], (vals >> 2) << 2)


# ---------------------------------------------------------------------------
# individual passes
# ---------------------------------------------------------------------------


def test_round_coeffs_truncates_to_canonical_subsets():
    for c in list(range(-200, 201)) + [2 ** 17 - 3]:
        if c == 0:
            continue
        digits = HW.csd_digits(c)
        for drop in range(len(digits) + 2):
            c2 = approx.truncate_csd(c, drop)
            kept = HW.csd_digits(c2)
            assert len(kept) == max(len(digits) - drop, 1)
            # kept digits are exactly the top digits of the original
            assert kept == sorted(digits)[len(digits) - len(kept):]


def test_round_coeffs_pass_reduces_csd_wires_and_is_sound():
    c = synth_compiled((8, 9, 4), 7, seed=21)
    net = circuit.compile_netlist(c)
    anet = approx.approximate(net, approx.ApproxParams((2, 2), (0, 0)))
    n_shl = lambda n: sum(1 for nd in n.nodes
                          if nd.role == ir.ROLE_MULT and nd.op == ir.Op.SHL)
    assert n_shl(anet) < n_shl(net)
    assert circuit.structural_cost(anet).total_fa \
        < circuit.structural_cost(net).total_fa
    _measured_ok(anet, c, RNG.random((31, 8)).astype(np.float32))


def test_power_of_two_limit_keeps_one_digit_per_multiplier():
    c = synth_compiled((6, 7, 3), 8, seed=5)
    net = circuit.compile_netlist(c)
    anet = approx.approximate(net, approx.ApproxParams((8, 8), (0, 0)))
    for n in anet.nodes:
        if n.product_root and n.role == ir.ROLE_MULT:
            _, coeff = approx.product_info(anet, n.id)
            assert HW.csd_nonzero_digits(coeff) == 1    # pure power of two
    _measured_ok(anet, c, RNG.random((17, 6)).astype(np.float32))


def test_truncate_accum_inserts_trunc_and_discounts_adders():
    c = synth_compiled((10, 12, 5), 8, seed=13)
    net = circuit.compile_netlist(c)
    anet = approx.approximate(net, approx.ApproxParams((0, 0), (3, 3)))
    assert any(n.op == ir.Op.TRUNC for n in anet.nodes)
    sc, asc = circuit.structural_cost(net), circuit.structural_cost(anet)
    # same adder count, each narrowed by up to 3 FA
    assert sum(l.n_adders for l in asc.layers) \
        == sum(l.n_adders for l in sc.layers)
    assert asc.total_fa < sc.total_fa
    measured, bound = _measured_ok(anet, c,
                                   RNG.random((29, 10)).astype(np.float32))
    assert bound > 0


def test_relu_elision_is_exact_when_provably_nonnegative():
    """All-positive weights + unsigned inputs push every pre-activation
    interval above zero: SimplifyActs removes the ReLUs bit-exactly."""
    c = synth_compiled((5, 6, 3), 6, seed=2)
    for q in c.q_layers:
        np.abs(q, out=q)
    for b in c.biases:
        np.abs(b, out=b)
    net = circuit.compile_netlist(c)
    assert any(n.op == ir.Op.RELU for n in net.nodes)
    anet = approx.passes.SimplifyActs().run(net)
    anet = approx.rewrite.rebuild(anet, dce=True)
    assert not any(n.op == ir.Op.RELU for n in anet.nodes)
    assert_bit_exact(anet, c, RNG.random((19, 5)).astype(np.float32))
    assert circuit.structural_cost(anet).total_fa \
        < circuit.structural_cost(net).total_fa


def test_argmax_truncation_narrows_comparator_and_bounds_decision():
    c = synth_compiled((7, 8, 4), 8, seed=9)
    net = circuit.compile_netlist(c)
    anet = approx.approximate(net, approx.ApproxParams((0, 0), (0, 0),
                                                       argmax_lsb=4))
    am = anet.nodes[anet.argmax_id]
    assert all(anet.nodes[a].op == ir.Op.TRUNC for a in am.args)
    assert approx.logit_error_bound(anet) == 0       # logits untouched
    assert approx.decision_error_bound(anet) == 2 ** 4 - 1
    assert circuit.structural_cost(anet).argmax_fa \
        < circuit.structural_cost(net).argmax_fa
    # the truncated comparator can only flip decisions within the bound:
    # exact logits and simulated argmax agree wherever the runner-up gap
    # exceeds twice the bound
    x = RNG.random((41, 7)).astype(np.float32)
    xq = MZ.quantize_inputs(c, x)
    pres, ref_cls = MZ.integer_forward(c, xq)
    got = circuit.Simulator(anet).run(xq)["argmax"]
    top2 = np.sort(pres[-1], axis=1)[:, -2:]
    clear = (top2[:, 1] - top2[:, 0]) > 2 * (2 ** 4 - 1)
    np.testing.assert_array_equal(got[clear], ref_cls[clear])


# ---------------------------------------------------------------------------
# soundness: randomized spec sweep + all four datasets
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dims,bits,sparsity,clusters", [
    ((7, 8, 3), 8, 0.0, None),
    ((11, 10, 7), 6, 0.5, None),
    ((11, 10, 7), 4, 0.0, 4),
    ((16, 20, 10), 8, 0.3, 8),
    ((5, 6, 6, 4), 7, 0.2, 3),
])
def test_soundness_randomized_specs(dims, bits, sparsity, clusters):
    c = synth_compiled(dims, bits, sparsity=sparsity, clusters=clusters,
                       seed=hash((dims, bits, 77)) % 2 ** 31)
    net = circuit.compile_netlist(c)
    L = net.n_layers
    r = np.random.default_rng(hash((dims, bits)) % 2 ** 31)
    x = RNG.random((23, dims[0])).astype(np.float32)
    for _ in range(4):
        p = approx.ApproxParams(
            tuple(int(v) for v in r.integers(0, 3, L)),
            tuple(int(v) for v in r.integers(0, 5, L)),
            int(r.integers(0, 5)))
        anet = approx.approximate(net, p)
        _measured_ok(anet, c, x)
        assert circuit.structural_cost(anet).total_fa \
            <= circuit.structural_cost(net).total_fa


@pytest.mark.parametrize("name", sorted(PRINTED_MLPS))
def test_soundness_on_dataset(name):
    cfg = PRINTED_MLPS[name]
    n_layers = len(cfg.layer_dims) - 1
    spec = ModelMin.uniform(n_layers, bits=4, sparsity=0.4, clusters=8,
                            input_bits=cfg.input_bits)
    params0, (_, _, xte, _) = MZ.pretrain(cfg)
    compiled = MZ.compile_bespoke(params0, spec,
                                  MZ.make_masks(params0, spec))
    net = circuit.compile_netlist(compiled)
    for p in (approx.ApproxParams((1,) * n_layers, (0,) * n_layers),
              approx.ApproxParams((0,) * n_layers, (3,) * n_layers),
              approx.ApproxParams((1,) * n_layers, (2,) * n_layers,
                                  argmax_lsb=3)):
        anet = approx.approximate(net, p)
        _measured_ok(anet, compiled, xte)


def test_fit_budget_respects_budget_and_shrinks_area():
    c = synth_compiled((9, 10, 5), 6, sparsity=0.3, clusters=4, seed=17)
    net = circuit.compile_netlist(c)
    budget = approx.logit_budget(net, 0.01)
    params, anet, rep = approx.fit_budget(net, budget)
    assert rep.bound <= budget
    assert not params.is_identity            # something was approximated
    assert rep.approx_fa < rep.exact_fa
    _measured_ok(anet, c, RNG.random((25, 9)).astype(np.float32))
    # zero budget -> identity knobs
    p0, _, rep0 = approx.fit_budget(net, 0)
    assert p0.is_identity and rep0.bound == 0


# ---------------------------------------------------------------------------
# search integration
# ---------------------------------------------------------------------------


def test_ga_gene_sampling_and_determinism_with_approx():
    import random

    from repro.core.ga import (CSD_DROP_CHOICES, LSB_CHOICES, GAConfig,
                               _mutate, _random_gene)
    cfg = GAConfig(csd_drop_choices=CSD_DROP_CHOICES,
                   lsb_choices=LSB_CHOICES)
    assert cfg.approx_enabled and not GAConfig().approx_enabled
    rng = random.Random(0)
    genes = [_random_gene(rng, cfg) for _ in range(64)]
    assert any(g.csd_drop for g in genes) and any(g.lsb for g in genes)
    spec = ModelMin.uniform(2, bits=4)
    muts = [_mutate(spec, rng, cfg) for _ in range(64)]
    assert any(m.has_approx for m in muts)
    # exact-config sampling is untouched (no extra RNG draws)
    r1, r2 = random.Random(5), random.Random(5)
    g1 = [_random_gene(r1, GAConfig()) for _ in range(8)]
    g2 = [_random_gene(r2, GAConfig()) for _ in range(8)]
    assert g1 == g2 and not any(g.csd_drop or g.lsb for g in g1)


def test_evaluate_population_approx_specs(tmp_path):
    from repro.core import batch_eval as BE
    cfg = PRINTED_MLPS["seeds"]
    n = len(cfg.layer_dims) - 1
    exact = ModelMin.uniform(n, bits=4, sparsity=0.4, clusters=8)
    ax = ModelMin.uniform(n, bits=4, sparsity=0.4, clusters=8,
                          csd_drop=1, lsb=2)
    cache = BE.EvalCache(tmp_path / "evals.json")
    # analytic opt-out: approx specs must STILL be forced onto the
    # simulated netlist (exact and approximated candidates compete on the
    # same datapath objective), while the exact twin takes the float path
    rs = BE.evaluate_population(cfg, [exact, ax], epochs=10, cache=cache,
                                netlist=False)
    # the approximated circuit must be strictly cheaper than its exact twin
    assert rs[1].area_mm2 < rs[0].area_mm2
    assert rs[1].delay_levels is not None
    assert 0.0 <= rs[1].accuracy <= 1.0
    # approx results live in the netlist keyspace; the exact one does not
    assert cache.get(cfg.name, 0, 10, ax, netlist=True) is not None
    assert cache.get(cfg.name, 0, 10, exact, netlist=True) is None
    assert cache.get(cfg.name, 0, 10, exact) is not None
    # cached re-evaluation returns identical numbers
    again = BE.evaluate_population(cfg, [exact, ax], epochs=10, cache=cache,
                                   netlist=False)
    assert again[1].area_mm2 == rs[1].area_mm2
    assert again[1].accuracy == rs[1].accuracy


def test_layermin_validate_rejects_bad_genes():
    with pytest.raises(AssertionError):
        LayerMin(4, 0.0, None, csd_drop=9).validate()
    with pytest.raises(AssertionError):
        LayerMin(4, 0.0, None, lsb=17).validate()
    with pytest.raises(AssertionError):
        ModelMin.uniform(1, bits=4, argmax_lsb=17).validate()
    ModelMin.uniform(1, bits=4, csd_drop=3, lsb=4, argmax_lsb=2).validate()
