"""Static-analysis layer: machine-checked invariants for the circuit
compiler, the approximation passes and the search stack.

Three analyzers and the hooks that make them ambient:

* `repro.verify.netlist` — re-derives every node's interval/width, the
  topo/level/depth analyses and the classifier bookkeeping independently
  of the IR's own code and reports structured `Diagnostic` records
  (`Netlist.validate()` delegates here; the pass pipeline and the
  compiler check their outputs in strict mode).
* `repro.verify.spec`    — lints `ModelMin` genomes before any costly
  QAT evaluation: gene-range/arch legality plus serialize->parse->
  serialize byte-stability (the EvalCache keyspace guard).
* `tools/jaxlint.py`     — repo-specific AST lint over ``src/`` (pure-int
  domain purity, tracer-hostile Python in jitted bodies, static_argnames
  hygiene); standalone, stdlib-only, run as a pytest test and a CI gate.

The ambient switch is the ``REPRO_VERIFY`` env var (`verify_enabled`):
the test suite turns it on in ``tests/conftest.py``, so every pass, every
compile and every population evaluation in CI is verified; production
sweeps leave it off and pay nothing.

`repro.verify.mutate` ships the seeded-corruption catalog the tests use
to prove the verifier actually catches each invariant class.
"""
from repro.verify.diagnostics import (ERROR, WARN, Diagnostic,  # noqa: F401
                                      VerificationError, errors,
                                      verify_enabled)
from repro.verify.netlist import (SIM_WIDTH_BUDGET,  # noqa: F401
                                  check_netlist, fits_int32, max_sim_width,
                                  node_widths, verify_netlist)
from repro.verify.spec import (check_specs, lint_spec,  # noqa: F401
                               lint_specs)
from repro.verify.mutate import CATALOG, Mutation, apply_mutation  # noqa: F401
