from repro.kernels.clustered_matmul.ops import clustered_matmul
from repro.kernels.clustered_matmul.ref import clustered_matmul_ref

__all__ = ["clustered_matmul", "clustered_matmul_ref"]
