"""Pallas kernel validation (interpret=True on CPU): shape/dtype sweeps
against the pure-jnp oracles, plus semantic links back to repro.core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clustering as C
from repro.core import pruning as P
from repro.core import quantization as Q
from repro.kernels.block_sparse_matmul import (block_sparse_matmul,
                                               block_sparse_matmul_ref)
from repro.kernels.clustered_matmul import (clustered_matmul,
                                            clustered_matmul_ref)
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.quant_matmul import quant_matmul, quant_matmul_ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,K,N", [(32, 64, 32), (100, 200, 72),
                                   (17, 130, 50)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_shapes_dtypes(M, K, N, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (M, K), dtype)
    wq = jax.random.randint(k2, (K, N), -127, 128, jnp.int8)
    s = (jnp.abs(jax.random.normal(k3, (N,))) + 0.1) * 0.01
    y = quant_matmul(x, wq, s, block_m=32, block_n=32, block_k=64)
    yr = quant_matmul_ref(x, wq, s)
    # fp32 headroom for split-K: the kernel accumulates K/block_k partial
    # tiles, the oracle one dot — reassociation costs a few ulp at K=200
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quant_matmul_consistent_with_core_quantizer(bits):
    """kernel(int weights from core.quantization) == x @ dequant(w)."""
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (48, 96), jnp.float32)
    w = jax.random.normal(k2, (96, 64), jnp.float32)
    q, scale = Q.quantize_int(w, Q.QuantConfig(bits=bits))
    scales = jnp.full((64,), jnp.float32(scale))
    y = quant_matmul(x, q.astype(jnp.int8), scales, block_m=16, block_n=32,
                     block_k=32)
    ref = x @ Q.dequantize(q, scale)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# clustered_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,K,N,C_", [(32, 64, 32, 4), (64, 128, 96, 16),
                                      (20, 70, 40, 3)])
def test_clustered_matmul_shapes(M, K, N, C_):
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (M, K), jnp.float32)
    idx = jax.random.randint(k2, (K, N), 0, C_, jnp.int32)
    cb = jax.random.normal(k3, (K, C_), jnp.float32)
    y = clustered_matmul(x, idx, cb, block_m=16, block_n=32, block_k=32)
    yr = clustered_matmul_ref(x, idx, cb)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5,
                               atol=1e-5)


def test_clustered_matmul_consistent_with_core_clustering():
    """kernel over core.clustering's per-input codebooks == dense matmul on
    the reconstructed weights (the paper's multiplier-sharing semantics)."""
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (24, 32), jnp.float32)
    w = jax.random.normal(k2, (32, 48), jnp.float32)
    cb, idx = C.cluster_per_input(w, 6)
    y = clustered_matmul(x, idx, cb, block_m=8, block_n=16, block_k=16)
    ref = x @ C.reconstruct_per_input(cb, idx)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# block_sparse_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sparsity", [0.0, 0.4, 0.8])
def test_block_sparse_matmul(sparsity):
    k1, k2 = jax.random.split(KEY)
    M, K, N, bk, bn = 32, 128, 96, 32, 32
    x = jax.random.normal(k1, (M, K), jnp.float32)
    w = jax.random.normal(k2, (K, N), jnp.float32)
    full = P.block_mask(w, sparsity, block=(bk, bn))
    bm = full[::bk, ::bn].astype(jnp.int32)
    y = block_sparse_matmul(x, w, bm, block_m=16, block_n=bn, block_k=bk)
    yr = block_sparse_matmul_ref(x, w, bm, block_k=bk, block_n=bn)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5,
                               atol=1e-5)
    # semantics match core.pruning.apply_mask
    ref2 = x @ P.apply_mask(w, full)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref2), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,H,KV,hd", [(1, 64, 4, 4, 16), (2, 128, 4, 2, 32),
                                         (1, 96, 8, 1, 16)])
def test_flash_attention_causal(B, T, H, KV, hd):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, T, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, KV, hd), jnp.float32)
    y = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    G = H // KV
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    kf = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (B, KV, G, T, hd)).reshape(B * H, T, hd)
    vf = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (B, KV, G, T, hd)).reshape(B * H, T, hd)
    yr = flash_attention_ref(qf, kf, vf, causal=True) \
        .reshape(B, H, T, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)


@pytest.mark.parametrize("window", [32, 64])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(KEY, 3)
    B, T, H, hd = 1, 128, 2, 16
    q = jax.random.normal(ks[0], (B, T, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, H, hd), jnp.float32)
    y = flash_attention(q, k, v, causal=True, window=window, block_q=32,
                        block_k=32)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    yr = flash_attention_ref(qf, kf, vf, causal=True, window=window) \
        .reshape(B, H, T, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)


def test_flash_attention_softcap_matches_model_attention():
    """Kernel agrees with the model's attend() (gemma2-style softcap)."""
    from repro.nn.attention import attend
    ks = jax.random.split(KEY, 3)
    B, T, H, hd = 1, 64, 2, 16
    q = jax.random.normal(ks[0], (B, T, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, H, hd), jnp.float32)
    y = flash_attention(q, k, v, causal=True, softcap=50.0, block_q=32,
                        block_k=32)
    yr = attend(q, k, v, causal=True, softcap=50.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)


def test_flash_attention_bf16():
    ks = jax.random.split(KEY, 3)
    B, T, H, hd = 1, 64, 2, 32
    q = jax.random.normal(ks[0], (B, T, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, T, H, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, T, H, hd), jnp.bfloat16)
    y = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    yr = flash_attention_ref(qf, kf, vf, causal=True) \
        .reshape(B, H, T, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), rtol=3e-2,
                               atol=3e-2)
