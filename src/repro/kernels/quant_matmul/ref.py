"""Pure-jnp oracle for quant_matmul."""
import jax.numpy as jnp


def quant_matmul_ref(x, w_q, scales):
    w = w_q.astype(jnp.float32) * scales.astype(jnp.float32)[None, :]
    return (x.astype(jnp.float32) @ w).astype(x.dtype)
