"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Single-host execution of the production train step (the multi-pod dry-run
proves the same step compiles on the 512-chip mesh). Supports resume,
periodic async checkpoints, preemption (SIGTERM), microbatching, and the
paper's compression spec as a first-class flag (--qat-bits / --sparsity /
--clusters apply the repro.core QAT forward to every matmul weight).
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.core import pruning as P
from repro.core import quantization as Q
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def make_compression(bits=None, sparsity=0.0, clusters=None):
    """params -> params QAT transform over >=2D weights (paper technique)."""
    if bits is None and not sparsity and clusters is None:
        return None
    from repro.core.clustering import cluster_ste

    def transform(params):
        def leaf(w):
            if w.ndim < 2 or w.size < 4096:
                return w
            out = w
            if sparsity:
                out = P.apply_mask(out, P.magnitude_mask(out, sparsity))
            if clusters is not None and out.ndim == 2:
                out = cluster_ste(out, clusters, per_input=False)
            if bits is not None:
                out = Q.fake_quant(out, Q.QuantConfig(bits=bits))
            return out
        return jax.tree_util.tree_map(leaf, params)

    return transform


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--qat-bits", type=int, default=None)
    ap.add_argument("--sparsity", type=float, default=0.0)
    ap.add_argument("--clusters", type=int, default=None)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    pipe = TokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch))
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(args.steps // 20, 1))
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, microbatch=args.microbatch)

    def extra(step):
        out = {}
        if cfg.encoder is not None:
            out["frames"] = jnp.zeros(
                (args.global_batch, cfg.encoder.num_frames, cfg.d_model))
        if cfg.vision is not None:
            out["patches"] = jnp.zeros(
                (args.global_batch, cfg.vision.num_patches, cfg.d_model))
        return out

    trainer = Trainer(cfg, opt, tcfg, pipe, extra_batch=extra)
    compression = make_compression(args.qat_bits, args.sparsity,
                                   args.clusters)
    if compression is not None:
        from repro.train import train_state as TS
        trainer.step_fn = jax.jit(TS.make_train_step(
            cfg, opt, remat=True, microbatch=args.microbatch,
            compression=compression))
    trainer.install_signal_handler()
    out = trainer.run()
    print(json.dumps({k: v for k, v in out.items() if k != "history"}))


if __name__ == "__main__":
    main()
