"""Pure-jnp oracle for clustered_matmul."""
import jax.numpy as jnp


def clustered_matmul_ref(x, idx, codebook):
    w = jnp.take_along_axis(codebook, idx.astype(jnp.int32), axis=1)
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)
