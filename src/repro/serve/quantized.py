"""Quantized serving: the paper's minimization techniques as serving-path
weight formats (DESIGN.md §3).

* int8/int4 weights with per-output-channel scales — every >=2D matmul weight
  leaf becomes {"q": intN, "scale": f32[last_dim]}. Dequant happens after the
  FSDP all-gather, so both the HBM-read term AND the weight all-gather
  collective term shrink by 2x/4x (the decode cells are bound by exactly
  these terms).
* fp8 (e4m3) KV cache — cache writes cast to fp8, reads upcast; halves the
  32k-context cache traffic at decode.

The dequantized forward reuses the unmodified model code: `serve_step_quant`
dequantizes leaf-by-leaf inside the jitted step (XLA keeps the gather on the
int payload and fuses the dequant into consumers).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import quantization as Q
from repro.nn import transformer as T


def _is_quantizable(path_str: str, leaf) -> bool:
    if len(leaf.shape) < 2 or leaf.shape[-1] < 64:
        return False
    import numpy as np
    return int(np.prod(leaf.shape)) >= (1 << 16)


def _qleaf_dtype(bits: int):
    if bits == 8:
        return jnp.int8
    if bits == 4:
        return jnp.int4
    raise ValueError(bits)


def is_qleaf(x) -> bool:
    return isinstance(x, dict) and set(x) == {"q", "scale"}


def quantize_params(params, bits: int = 8):
    """Real arrays -> quantized pytree (per-channel symmetric)."""
    from repro.dist.sharding import path_str

    def leaf(path, w):
        if not _is_quantizable(path_str(path), w):
            return w
        qmax = 2.0 ** (bits - 1) - 1.0
        amax = jnp.max(jnp.abs(w.astype(jnp.float32)),
                       axis=tuple(range(w.ndim - 1)))
        scale = jnp.maximum(amax, 1e-8) / qmax
        q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -qmax, qmax)
        return {"q": q.astype(_qleaf_dtype(bits)),
                "scale": scale.astype(jnp.float32)}

    return jax.tree_util.tree_map_with_path(leaf, params)


def abstract_quantized(params_shapes, bits: int = 8):
    """ShapeDtypeStruct pytree -> quantized abstract pytree (dry-run path)."""
    from repro.dist.sharding import path_str

    def leaf(path, w):
        if not _is_quantizable(path_str(path), w):
            return w
        return {"q": jax.ShapeDtypeStruct(w.shape, _qleaf_dtype(bits)),
                "scale": jax.ShapeDtypeStruct(w.shape[-1:], jnp.float32)}

    return jax.tree_util.tree_map_with_path(leaf, params_shapes)


def dequantize_params(qparams, dtype=jnp.bfloat16):
    def leaf(x):
        if is_qleaf(x):
            return (x["q"].astype(jnp.float32) * x["scale"]).astype(dtype)
        return x
    return jax.tree_util.tree_map(leaf, qparams, is_leaf=is_qleaf)


def make_quant_serve_step(cfg: ArchConfig, *, unroll: bool = False):
    dtype = jnp.dtype(cfg.dtype)

    def serve_step(qparams, state, tokens):
        params = dequantize_params(qparams, dtype)
        logits, state = T.decode_step(params, state, tokens, cfg,
                                      unroll=unroll)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, state

    return serve_step


def quantized_shardings(cfg: ArchConfig, mesh, params_shapes, bits: int = 8,
                        fsdp: bool = True):
    """q inherits the original weight's sharding; scales replicate.
    fsdp=False drops the data-axis weight sharding (TP-only serving)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist.sharding import param_specs
    specs = param_specs(params_shapes, mesh, fsdp_enabled=fsdp)

    qshapes = abstract_quantized(params_shapes, bits)

    def merge(spec, orig_leaf, q_leaf):
        if is_qleaf(q_leaf):
            return {"q": NamedSharding(mesh, spec),
                    "scale": NamedSharding(mesh, P())}
        return NamedSharding(mesh, spec)

    flat_spec = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(
            x, jax.sharding.PartitionSpec))
    flat_orig = jax.tree_util.tree_leaves(params_shapes)
    treedef = jax.tree_util.tree_structure(params_shapes)
    flat_q = treedef.flatten_up_to(qshapes)
    merged = [merge(s, o, q) for s, o, q in zip(flat_spec, flat_orig, flat_q)]
    return jax.tree_util.tree_unflatten(treedef, merged), qshapes
