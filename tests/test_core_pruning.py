"""Unit + property tests for pruning (paper §II-B)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import pruning as P


def test_magnitude_mask_keeps_largest():
    w = jnp.asarray([[0.1, -5.0], [0.01, 2.0]])
    m = P.magnitude_mask(w, 0.5)
    assert bool(m[0, 1]) and bool(m[1, 1])
    assert not bool(m[0, 0]) and not bool(m[1, 0])


@settings(max_examples=30, deadline=None)
@given(sparsity=st.floats(0.0, 0.85), seed=st.integers(0, 2 ** 16))
def test_property_sparsity_achieved(sparsity, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (40, 25))
    m = P.magnitude_mask(w, sparsity)
    achieved = 1.0 - float(jnp.mean(m.astype(jnp.float32)))
    assert abs(achieved - sparsity) < 0.02


def test_masked_gradient_is_dead():
    w = jax.random.normal(jax.random.PRNGKey(0), (8, 8))
    m = P.magnitude_mask(w, 0.5)
    g = jax.grad(lambda w: jnp.sum(P.apply_mask(w, m)))(w)
    assert bool(jnp.all((np.asarray(g) != 0) == np.asarray(m)))


def test_global_pruning_spares_small_leaves():
    params = {"w1": jax.random.normal(jax.random.PRNGKey(0), (32, 32)),
              "b": jnp.ones((4,))}
    masks = P.global_magnitude_masks(params, 0.5)
    assert bool(jnp.all(masks["b"]))
    assert 0.4 < P.sparsity_of({"w1": masks["w1"]}) < 0.6


def test_block_mask_structure():
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    m = P.block_mask(w, 0.5, block=(16, 16))
    tiles = np.asarray(m).reshape(4, 16, 4, 16)
    per_tile = tiles.all(axis=(1, 3)) | (~tiles.any(axis=(1, 3)))
    assert per_tile.all(), "mask must be constant within each block"
    assert abs(1.0 - m.mean() - 0.5) < 0.1


def test_cubic_schedule_monotone():
    vals = [P.cubic_schedule(s, begin=10, end=100, final=0.8)
            for s in range(0, 120, 5)]
    assert vals[0] == 0.0 and abs(vals[-1] - 0.8) < 1e-9
    assert all(b >= a - 1e-9 for a, b in zip(vals, vals[1:]))


def test_neuron_mask_columns():
    w = jax.random.normal(jax.random.PRNGKey(2), (16, 10))
    m = np.asarray(P.neuron_mask(w, 0.3))
    col_const = np.all(m == m[0:1, :], axis=0)
    assert col_const.all()
    assert m[0].sum() == 7
