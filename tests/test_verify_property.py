"""Property-based verification tests: randomized exact netlists pushed
through randomized approximation pipelines stay verifier-clean, the
rebuild walk is idempotent under DCE, and every seeded corruption from the
mutation catalog is caught — on arbitrary architectures, not just the
fixtures in test_verify.py. Degrades to clean skips without hypothesis
(see tests/_hypothesis_compat.py)."""
import numpy as np

from repro import approx, circuit
from repro.approx.budget import ApproxParams
from repro.approx.rewrite import rebuild
from repro.verify import (CATALOG, ERROR, apply_mutation, verify_netlist)

from _hypothesis_compat import given, settings, st
from test_circuit import synth_compiled


def _random_case(seed: int):
    """Seed -> (exact compiled netlist, random knob vector). Shapes stay
    small enough that the 62-bit sim budget can never trip."""
    r = np.random.default_rng(seed)
    dims = (int(r.integers(3, 10)), int(r.integers(3, 10)),
            int(r.integers(2, 6)))
    bits = int(r.integers(2, 6))
    clusters = int(r.integers(2, 6)) if r.random() < 0.5 else None
    c = synth_compiled(dims, bits, sparsity=float(r.uniform(0.0, 0.7)),
                       clusters=clusters, seed=seed % 997)
    net = circuit.compile_netlist(c)
    p = ApproxParams(tuple(int(r.integers(0, 3)) for _ in range(2)),
                     tuple(int(r.integers(0, 3)) for _ in range(2)),
                     int(r.integers(0, 4)))
    return net, p


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_random_pipelines_verifier_clean(seed):
    net, p = _random_case(seed)
    assert verify_netlist(net, expect_exact=True, expect_dce=True) == []
    anet = approx.approximate(net, p)
    assert verify_netlist(anet, expect_dce=True) == []
    # the proven bound is a sound overestimate of the exact-vs-approx gap
    assert approx.decision_error_bound(anet) >= 0


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_rebuild_dce_idempotent(seed):
    net, p = _random_case(seed)
    anet = approx.approximate(net, p)
    once = rebuild(anet, dce=True)
    twice = rebuild(once, dce=True)
    assert [(n.op, n.args, n.value, n.shift, n.lo, n.hi, n.role, n.layer,
             n.unit, n.err_lo, n.err_hi) for n in once.nodes] \
        == [(n.op, n.args, n.value, n.shift, n.lo, n.hi, n.role, n.layer,
             n.unit, n.err_lo, n.err_hi) for n in twice.nodes]
    assert circuit.structural_cost(once).total_fa \
        == circuit.structural_cost(twice).total_fa


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10**6),
       st.integers(min_value=0, max_value=len(CATALOG) - 1))
def test_catalog_caught_on_random_nets(seed, mi):
    net, p = _random_case(seed)
    anet = approx.approximate(net, p)
    m = CATALOG[mi]
    bad = apply_mutation(anet, m) or apply_mutation(net, m)
    if bad is None:          # mutation needs structure this net lacks
        return
    diags = verify_netlist(bad, expect_dce=m.needs_dce)
    fatal = {d.rule for d in diags
             if d.severity == ERROR or m.strict_only}
    assert fatal & m.rules, (
        f"seed={seed}: {m.name} escaped — got "
        f"{sorted((d.severity, d.rule) for d in diags)}")
