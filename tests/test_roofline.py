"""Roofline analysis unit tests: HLO collective parsing, affine depth fit,
term arithmetic."""
import numpy as np
import pytest

from repro.roofline import analysis as RA
from repro.roofline.hw import TPU_V5E


HLO = """
ENTRY main {
  %p = bf16[16,4096,1152]{2,1,0} parameter(0)
  %ag = bf16[16,4096,18432]{2,1,0} all-gather(%p), dimensions={2}
  %ar.1 = f32[256,1024]{1,0} all-reduce-start(f32[256,1024]{1,0} %x)
  %ar.1d = f32[256,1024]{1,0} all-reduce-done(%ar.1)
  %rs = f32[64,512]{1,0} reduce-scatter(%y), dimensions={0}
  %cp = u8[1024]{0} collective-permute(%z)
}
"""


def test_collective_parsing_kinds_and_bytes():
    out = RA.collective_bytes(HLO)
    ag = 16 * 4096 * 18432 * 2
    ar = 256 * 1024 * 4 * 2.0            # wire factor 2 for all-reduce
    rs = 64 * 512 * 4
    cp = 1024
    assert out["all-gather"] == ag
    assert out["all-reduce"] == ar       # -start counted once, -done ignored
    assert out["reduce-scatter"] == rs
    assert out["collective-permute"] == cp
    assert out["total"] == ag + ar + rs + cp


def test_affine_depth_fit_exact():
    """cost(R) = 7 + 3*R0 + 11*R1 must be recovered exactly."""
    def measure(r):
        return {"flops": 7.0 + 3.0 * r[0] + 11.0 * r[1]}
    fit = RA.fit_depth(measure, 2)
    assert fit.base["flops"] == pytest.approx(7.0)
    assert fit.bodies[0]["flops"] == pytest.approx(3.0)
    assert fit.bodies[1]["flops"] == pytest.approx(11.0)
    assert fit.at([96, 4])["flops"] == pytest.approx(7 + 3 * 96 + 11 * 4)


def test_roofline_terms_and_dominant():
    r = RA.Roofline(flops_per_chip=197e12, bytes_per_chip=819e9 * 2,
                    coll_bytes_per_chip=50e9 * 0.5)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.dominant == "memory"
    assert r.t_step == pytest.approx(2.0)
    assert r.t_serial == pytest.approx(3.5)


def test_model_flops():
    assert RA.model_flops(1e9, 1000, "train") == 6e12
    assert RA.model_flops(1e9, 1000, "serve") == 2e12


def test_dryrun_artifacts_consistent():
    """Every recorded single-pod cell: terms recompute from raw fields."""
    import glob
    import json
    files = glob.glob("artifacts/dryrun/*__single.json")
    if not files:
        pytest.skip("dry-run artifacts not generated yet")
    checked = 0
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        ro = r["roofline"]
        assert ro["t_compute_s"] == pytest.approx(
            ro["flops_per_chip"] / TPU_V5E.peak_flops)
        assert ro["t_memory_s"] == pytest.approx(
            ro["bytes_per_chip"] / TPU_V5E.hbm_bw)
        assert ro["t_step_s"] == pytest.approx(
            max(ro["t_compute_s"], ro["t_memory_s"], ro["t_collective_s"]))
        assert ro["dominant"] in ("compute", "memory", "collective")
        checked += 1
    assert checked >= 10
