"""Batched serving engine: continuous-batching-lite over the one-token
`serve_step` with per-slot request lifecycle.

Slots: fixed `batch` decode lanes. A request occupies a slot from prefill to
EOS/max-tokens; freed slots are immediately refilled from the queue
(continuous batching). Prefill feeds prompt tokens through `decode_step`
token-by-token per-slot (exact w.r.t. ring buffers and recurrent state);
chunked prefill is the TPU-side optimization documented in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.nn import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens_generated: int = 0
    requests_completed: int = 0


class ServeEngine:
    """Single-host reference engine (the dry-run serves the multi-pod path).

    greedy sampling; per-slot kv_len tracking is implicit: all slots share
    the global kv_len counter, so slots are refilled only at a batch barrier
    when every active request finished (barrier batching). True per-slot
    lengths need a paged cache — noted as future work in DESIGN.md.
    """

    def __init__(self, params, cfg: ArchConfig, *, batch: int = 4,
                 max_len: int = 256, dtype=jnp.float32):
        self.params = params
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.dtype = dtype
        self.stats = EngineStats()
        self._step = jax.jit(
            lambda p, s, t: T.decode_step(p, s, t, cfg))

    def _fresh_state(self, enc_out=None):
        state = T.init_decode_state(self.cfg, self.batch, self.max_len,
                                    self.dtype)
        if enc_out is not None:
            state["enc_out"] = enc_out
        return state

    def run(self, requests: List[Request], *, enc_out=None) -> List[Request]:
        """Process all requests to completion, batch-barrier batching."""
        queue = list(requests)
        while queue:
            wave, queue = queue[:self.batch], queue[self.batch:]
            self._run_wave(wave, enc_out)
        return requests

    def _run_wave(self, wave: List[Request], enc_out):
        state = self._fresh_state(enc_out)
        B = self.batch
        maxp = max(len(r.prompt) for r in wave)
        # left-pad prompts to a rectangle with their own first token
        toks = np.zeros((B, maxp), np.int32)
        for i, r in enumerate(wave):
            toks[i, maxp - len(r.prompt):] = r.prompt
        logits = None
        for t in range(maxp):
            logits, state = self._step(self.params, state,
                                       jnp.asarray(toks[:, t:t + 1]))
            self.stats.steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1)).astype(np.int32)
        max_new = max(r.max_new_tokens for r in wave)
        for _ in range(max_new):
            for i, r in enumerate(wave):
                if not r.done and len(r.output) < r.max_new_tokens:
                    r.output.append(int(nxt[i]))
                    self.stats.tokens_generated += 1
                    if r.eos_id is not None and nxt[i] == r.eos_id:
                        r.done = True
            if all(r.done or len(r.output) >= r.max_new_tokens for r in wave):
                break
            logits, state = self._step(self.params, state,
                                       jnp.asarray(nxt[:, None]))
            self.stats.steps += 1
            nxt = np.asarray(jnp.argmax(logits[:, -1], -1)).astype(np.int32)
        for r in wave:
            r.done = True
            self.stats.requests_completed += 1
