"""Checkpoint/resume runtime over the island fleet.

The full search state — per-island populations, RNG streams
(`random.Random.getstate()` Mersenne words stored as a checkpoint leaf),
generation counters, histories, the shared evaluation memo, fleet events
and quarantine records — is snapshotted through `ckpt.CheckpointManager`
(atomic tmp-dir rename, keep-N retention) on the
`dist.fault_tolerance.should_checkpoint_now` cadence, with an immediate
flush when the fault harness (or a real preemption notice) requests it.

`SearchRuntime.resume` restores the latest snapshot and continues; because
`ga_generation` consumes exactly the restored RNG stream and the restored
memo answers every already-done evaluation, the resumed search is
**bit-identical** to the uninterrupted one — the resume-equivalence tests
assert byte-equal Pareto fronts for kills at every round.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.core import ga as GA
from repro.core.compression_spec import ModelMin
from repro.core.pareto import pareto_front
from repro.dist import fault_tolerance as FT
from repro.obs import metrics as MT
from repro.obs import prof as PF
from repro.obs import trace as TR
from repro.obs.ring import RingLog
from repro.search.islands import IslandConfig, IslandFleet


class PreemptedError(RuntimeError):
    """The round loop was preempted after flushing a checkpoint. Callers
    resume with `SearchRuntime.resume(...)` — nothing is lost."""


@dataclasses.dataclass
class SearchConfig:
    n_layers: int
    rounds: int = 8                   # fleet-wide generations
    ga: GA.GAConfig = dataclasses.field(default_factory=GA.GAConfig)
    islands: IslandConfig = dataclasses.field(default_factory=IslandConfig)
    checkpoint_every: int = 0         # rounds; 0 = preemption-flush only
    keep: int = 3                     # CheckpointManager retention


@dataclasses.dataclass
class SearchResult:
    """Fleet-merged outcome: the Pareto front over EVERY evaluation any
    island ever completed (a dead island's work still counts)."""
    front_specs: List[ModelMin]
    front_objectives: np.ndarray      # (F, K), row-aligned with front_specs
    evaluations: Dict[str, Tuple[float, ...]]
    islands: List[GA.GAState]
    events: List[Dict]
    quarantined: List
    rounds: int


class SearchRuntime:
    """Drive an `IslandFleet` to `cfg.rounds` with checkpointing.

    ``harness`` (see `search.faults.FaultHarness`) is duck-typed:
    ``arrival_time(island, round)``, ``island_kill_hook(island, round)``,
    ``preemption_requested(round)``, ``before_round(round, runtime)``.
    ``eval_cache`` is flushed alongside every checkpoint so the on-disk
    evaluation store is at least as fresh as the search snapshot.
    Checkpoint writes are synchronous: search state is kilobytes, and a
    preemption flush must complete before the process dies.
    """

    def __init__(self, cfg: SearchConfig, *, evaluate=None,
                 batch_evaluate=None, ckpt_root=None, harness=None,
                 eval_cache=None,
                 seed_specs: Optional[List[ModelMin]] = None,
                 quarantine: Optional[List] = None):
        self.cfg = cfg
        self.harness = harness
        self.eval_cache = eval_cache
        self.mgr = (CheckpointManager(ckpt_root, keep=cfg.keep,
                                      async_write=False)
                    if ckpt_root is not None else None)
        self.fleet = IslandFleet(
            cfg.n_layers, cfg.ga, cfg.islands,
            evaluate=evaluate, batch_evaluate=batch_evaluate,
            seed_specs=seed_specs,
            timer=(harness.arrival_time if harness is not None else None),
            kill_hook=(harness.island_kill_hook if harness is not None
                       else None),
            quarantine=quarantine)

    # -- driving ------------------------------------------------------------

    def run(self) -> SearchResult:
        while self.fleet.round < self.cfg.rounds:
            r = self.fleet.round
            if self.harness is not None:
                self.harness.before_round(r, self)
            self.fleet.run_round()
            preempt = bool(self.harness is not None
                           and self.harness.preemption_requested(r))
            if self.mgr is not None and FT.should_checkpoint_now(
                    self.fleet.round, every=self.cfg.checkpoint_every,
                    preemption_requested=preempt):
                self.checkpoint()
            if preempt:
                TR.event("runtime.preempt", round=self.fleet.round,
                         checkpointed=self.mgr is not None)
                TR.flush()        # the process is about to die: drain now
                raise PreemptedError(
                    f"preempted after round {self.fleet.round} "
                    "(checkpoint flushed)" if self.mgr is not None else
                    f"preempted after round {self.fleet.round} "
                    "(NO checkpoint root configured)")
        return self.result()

    def result(self) -> SearchResult:
        fleet = self.fleet
        keys = sorted(fleet.evaluations)
        if keys:
            objs = np.asarray([fleet.evaluations[k] for k in keys], float)
            front = sorted(int(i) for i in pareto_front(objs))
            front_specs = [ModelMin.from_json(keys[i]) for i in front]
            front_objs = objs[front]
        else:
            front_specs, front_objs = [], np.zeros((0, 0))
        return SearchResult(front_specs, front_objs,
                            dict(fleet.evaluations),
                            [isl.state for isl in fleet.islands],
                            list(fleet.events), list(fleet.quarantine),
                            fleet.round)

    # -- checkpointing ------------------------------------------------------

    def checkpoint(self) -> None:
        if self.mgr is None:
            raise RuntimeError("no checkpoint root configured")
        # the metrics snapshot is packed BEFORE the write is accounted, so
        # the restored registry reflects exactly the counters at save time
        # (write timings live in histograms, outside the bit-identity
        # invariant — see repro.obs.metrics)
        tree, meta = self._pack()
        with TR.span("runtime.checkpoint", round=self.fleet.round) as sp:
            t0 = time.monotonic()
            self.mgr.save(self.fleet.round, tree, meta=meta)
            ms = (time.monotonic() - t0) * 1e3
            MT.histogram("ckpt.write_ms").observe(ms)
            if TR.active():
                step_dir = (self.mgr.root
                            / f"step_{self.fleet.round:08d}")
                nbytes = sum(f.stat().st_size
                             for f in step_dir.iterdir() if f.is_file())
                MT.histogram("ckpt.write_bytes").observe(nbytes)
                sp.set(bytes=nbytes, ms=round(ms, 3))
            if self.eval_cache is not None:
                self.eval_cache.flush()

    def _pack(self):
        islands = self.fleet.islands
        rngs, versions, gauss = [], [], []
        for isl in islands:
            version, internal, g = isl.state.rng_state
            # 624 Mersenne words + stream position, all < 2**32
            rngs.append(np.asarray(internal, np.uint64))
            versions.append(int(version))
            gauss.append(g)
        tree = {
            "rng": np.stack(rngs),
            "generation": np.asarray([isl.state.generation
                                      for isl in islands], np.int64),
        }
        meta = {
            "round": self.fleet.round,
            "populations": [[s.to_json() for s in isl.state.population]
                            for isl in islands],
            "history": [isl.state.history for isl in islands],
            "alive": [isl.alive for isl in islands],
            "ejections": [isl.ejections for isl in islands],
            "last_duration_s": [isl.last_duration_s for isl in islands],
            "rng_version": versions,
            "rng_gauss": gauss,
            "evaluations": {k: list(v)
                            for k, v in self.fleet.evaluations.items()},
            # rings persist their resident tail + true totals; the obs
            # trace (when on) holds the complete streams
            "events": list(self.fleet.events),
            "events_total": getattr(self.fleet.events, "total",
                                    len(self.fleet.events)),
            "quarantined": [dataclasses.asdict(q)
                            for q in self.fleet.quarantine],
            "quarantine_total": getattr(self.fleet.quarantine, "total",
                                        len(self.fleet.quarantine)),
            # the whole metrics registry rides along so resume() restores
            # monotone counters bit-identically
            "metrics": MT.snapshot(),
            # the executable observatory too: a resumed run keeps its
            # executable history (dispatch counts, captured cost/memory)
            # even though the fresh process rebuilds the executables
            "profile": PF.snapshot(),
        }
        return tree, meta

    @classmethod
    def resume(cls, cfg: SearchConfig, ckpt_root, *, evaluate=None,
               batch_evaluate=None, harness=None, eval_cache=None,
               quarantine: Optional[List] = None,
               step: Optional[int] = None) -> "SearchRuntime":
        """Rebuild a runtime from the latest (or ``step``) checkpoint.
        Continue with ``.run()`` — the continuation is bit-identical to the
        run that was killed."""
        mgr = CheckpointManager(ckpt_root, keep=cfg.keep, async_write=False)
        with TR.span("runtime.resume") as sp:
            tree, meta = mgr.restore(step, like={"rng": 0, "generation": 0})
            if tree is not None:
                sp.set(round=int(meta["round"]))
        if tree is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_root}")
        rt = cls(cfg, evaluate=evaluate, batch_evaluate=batch_evaluate,
                 ckpt_root=ckpt_root, harness=harness,
                 eval_cache=eval_cache, quarantine=quarantine)
        fleet = rt.fleet
        for i, isl in enumerate(fleet.islands):
            internal = tuple(int(x) for x in np.asarray(tree["rng"][i]))
            isl.state = GA.GAState(
                population=[ModelMin.from_json(s)
                            for s in meta["populations"][i]],
                rng_state=(int(meta["rng_version"][i]), internal,
                           meta["rng_gauss"][i]),
                generation=int(tree["generation"][i]),
                history=list(meta["history"][i]))
            isl.alive = bool(meta["alive"][i])
            isl.ejections = int(meta["ejections"][i])
            isl.last_duration_s = float(meta["last_duration_s"][i])
        fleet.round = int(meta["round"])
        fleet.evaluations = {k: tuple(v)
                             for k, v in meta["evaluations"].items()}
        fleet.events[:] = list(meta["events"])
        if isinstance(fleet.events, RingLog):
            fleet.events.total = int(meta.get("events_total",
                                              len(fleet.events)))
        # in-place so a caller-shared quarantine list (also wired into the
        # evaluator) keeps collecting into the same object
        fleet.quarantine[:] = [_record_from_dict(q)
                               for q in meta["quarantined"]]
        if isinstance(fleet.quarantine, RingLog):
            fleet.quarantine.total = int(meta.get(
                "quarantine_total", len(fleet.quarantine)))
        # restored counters are bit-identical to the values at save time:
        # the continuation increments from exactly where the dead run stood
        MT.restore(meta.get("metrics"))
        # executable registry restores dict-equal (checkpoints predating
        # the observatory restore to empty)
        PF.restore(meta.get("profile"))
        return rt


def _record_from_dict(d: Dict):
    from repro.core.batch_eval import QuarantineRecord
    return QuarantineRecord(**d)


__all__ = ["PreemptedError", "SearchConfig", "SearchResult", "SearchRuntime"]
