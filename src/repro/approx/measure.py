"""Measured (simulation-based) counterparts of the interval analysis.

`approx.analyze` is deliberately pure-Python-int (the jaxlint int-domain
purity gate enforces that it never touches numpy/jax — the proofs must not
depend on float semantics). Anything that *simulates* on real inputs lives
here instead.
"""
from __future__ import annotations

from repro.circuit import ir


def measured_max_logit_error(net: ir.Netlist, compiled, x: "object") -> int:
    """Measured counterpart of `analyze.logit_error_bound` on real inputs:
    simulate the (approximated) netlist and compare its integer logits
    against the exact reference `minimize.integer_forward`. Soundness
    demands measured <= predicted on every input (tested across all
    datasets)."""
    import numpy as np

    from repro.circuit.simulate import Simulator
    from repro.core import minimize as MZ

    xq = MZ.quantize_inputs(compiled, x)
    got = Simulator(net).run(xq)["logits"]
    ref = MZ.integer_forward(compiled, xq)[0][-1]
    return int(np.abs(np.asarray(got, np.int64) - ref).max(initial=0))
