"""Executable observatory: a process-wide registry of jit executables.

Every instrumented jit boundary (the six kernel wrappers, the population
QAT finetune, the netlist engines) dispatches through
:func:`dispatch(site, key, lower=...) <dispatch>`, where ``key`` is the
exact static-shape specialization tuple the jit will compile one
executable per. The registry records, per key:

* the trigger **site** and a **signature hash** of the lowered avals;
* first-compile **cost/memory analysis** (FLOPs, bytes accessed,
  generated-code/argument/output/temp bytes) captured through
  `repro.obs.xprof` on the first dispatch — read off AOT artifacts,
  never by rewriting the computation;
* **compile events** observed while the dispatch ran (count + seconds,
  via the ``jax.monitoring`` backend-compile listener), which makes a
  *recompile* — a compile firing on a key already dispatched — a
  first-class, assertable quantity instead of a mystery slowdown;
* a per-key **dispatch count** (the per-bucket dispatch histogram).

Everything rides the ambient ``REPRO_TRACE`` switch exactly like
`repro.obs.trace`: with tracing off, :func:`dispatch` is never even
called (instrumented wrappers keep their early-return fast path), the
registry is never touched and no listener sink is attached — provably
zero overhead and zero behavior change. With tracing on, each dispatch
additionally emits ``prof.compile`` / ``prof.executable`` trace events
so `repro.obs.report` can rebuild the registry post-hoc from the JSONL.

`search.runtime.SearchRuntime` snapshots the registry into every
checkpoint and ``resume()`` restores it dict-equal (same contract as the
metrics registry) — so a resumed search keeps its executable history
even though the fresh process will rebuild the executables themselves.

Note the checkpoint/bit-identity carve-out: compile counts live HERE,
not in `repro.obs.metrics` counters — a preempted+resumed run recompiles
every executable in the fresh process, so compile counts can never
satisfy the counters' bit-identity invariant and must stay out of that
registry.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable, Dict, Optional

from repro.obs import trace as TR
from repro.obs import xprof

_LOCK = threading.Lock()


class ExecutableRegistry:
    """Keyed store of executable records + process compile totals.

    Records are plain JSON-able dicts::

        {"site": str, "signature": str?, "dispatches": int,
         "compiles": int, "compile_s": float,
         "aot_compiles": int, "aot_compile_s": float,
         "flops": float?, "bytes_accessed": float?, <memory fields>?}
    """

    def __init__(self):
        self.executables: Dict[str, Dict[str, Any]] = {}
        self.compiles = 0
        self.compile_s = 0.0
        self.aot_compiles = 0
        self.aot_compile_s = 0.0

    # -- record surface ------------------------------------------------------

    def record(self, site: str, key: str) -> Dict[str, Any]:
        """Get-or-create the record for ``key`` (thread-safe)."""
        rec = self.executables.get(key)
        if rec is None:
            with _LOCK:
                rec = self.executables.setdefault(key, {
                    "site": site, "dispatches": 0,
                    "compiles": 0, "compile_s": 0.0,
                    "aot_compiles": 0, "aot_compile_s": 0.0})
        return rec

    def on_compile(self, rec: Optional[Dict[str, Any]], seconds: float,
                   aot: bool) -> None:
        with _LOCK:
            if aot:
                self.aot_compiles += 1
                self.aot_compile_s += seconds
            else:
                self.compiles += 1
                self.compile_s += seconds
            if rec is not None:
                k = "aot_compiles" if aot else "compiles"
                rec[k] += 1
                rec[k[:-1] + "_s"] = rec.get(k[:-1] + "_s", 0.0) + seconds

    def reset(self) -> None:
        with _LOCK:
            self.executables.clear()
            self.compiles = 0
            self.compile_s = 0.0
            self.aot_compiles = 0
            self.aot_compile_s = 0.0

    # -- checkpoint surface --------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able, keys sorted — byte-stable for equal states (the same
        convention as `metrics.MetricsRegistry.snapshot`)."""
        with _LOCK:
            return {
                "executables": {k: {f: x for f, x in sorted(v.items())
                                    if not f.startswith("_")}
                                for k, v in sorted(self.executables.items())},
                "totals": {"aot_compile_s": self.aot_compile_s,
                           "aot_compiles": self.aot_compiles,
                           "compile_s": self.compile_s,
                           "compiles": self.compiles},
            }

    def restore(self, snap: Optional[Dict[str, Any]]) -> None:
        """Replace state with a snapshot's — exact, so a restored registry
        is dict-equal to the one at save time. Tolerates missing sections
        (checkpoints predating the observatory restore to empty)."""
        self.reset()
        if not snap:
            return
        with _LOCK:
            for k, v in snap.get("executables", {}).items():
                self.executables[k] = dict(v)
            t = snap.get("totals", {})
            self.compiles = int(t.get("compiles", 0))
            self.compile_s = float(t.get("compile_s", 0.0))
            self.aot_compiles = int(t.get("aot_compiles", 0))
            self.aot_compile_s = float(t.get("aot_compile_s", 0.0))


# the process-wide registry (one executable cache per process — jax's)
REGISTRY = ExecutableRegistry()

_current = threading.local()        # .stack: records of in-flight dispatches
_sink_attached = False


def _dispatch_stack():
    st = getattr(_current, "stack", None)
    if st is None:
        st = _current.stack = []
    return st


def _sink(seconds: float, aot: bool) -> None:
    """The registry's compile-listener sink: only observes while tracing
    is on (profiling == tracing), attributes each backend compile to the
    innermost in-flight dispatch."""
    if not TR.active():
        return
    st = _dispatch_stack()
    rec = st[-1] if st else None
    REGISTRY.on_compile(rec, seconds, aot)
    TR.event("prof.compile", site=rec["site"] if rec else None,
             key=rec["_key"] if rec else None,
             seconds=round(seconds, 6), aot=bool(aot))


def _ensure_sink() -> None:
    global _sink_attached
    if not _sink_attached:
        with _LOCK:
            if not _sink_attached:
                xprof.add_sink(_sink)
                _sink_attached = True


def profiling() -> bool:
    """Profiling is on iff tracing is on (one ambient switch)."""
    return TR.active()


def key_str(key: Any) -> str:
    return key if isinstance(key, str) else repr(key)


@contextlib.contextmanager
def dispatch(site: str, key: Any, *,
             lower: Optional[Callable[[], Any]] = None, **attrs):
    """Wrap one dispatch of a jit'd callable specialized on ``key``.

    Must be called only when :func:`profiling` — instrumented wrappers
    keep their ``if not TR.active(): return fast_path()`` head, so the
    off path never reaches here. The body should ``block_until_ready``
    its result so the span covers real execution.

    ``lower`` is a zero-arg thunk returning the ``Lowered`` for exactly
    this call's arguments; on the first dispatch of ``key`` its
    cost/memory analyses are captured into the registry (the AOT compile
    this needs on jax 0.4.x is flagged and never counted as a recompile).
    """
    _ensure_sink()
    kstr = key_str(key)
    first = TR.first_call(key)
    rec = REGISTRY.record(site, kstr)
    rec["_key"] = kstr              # for sink attribution; dropped below
    st = _dispatch_stack()
    st.append(rec)
    try:
        with TR.span(site, key=kstr, first=first, **attrs) as sp:
            yield sp
    finally:
        st.pop()
        rec.pop("_key", None)
        with _LOCK:
            rec["dispatches"] += 1
    if "signature" not in rec and lower is not None:
        cap = xprof.capture_executable(lower)
        with _LOCK:
            for k, v in cap.items():
                rec.setdefault(k, v)
            rec.setdefault("signature", "")
        TR.event("prof.executable", site=site, key=kstr, **{
            k: v for k, v in sorted(rec.items())
            if k not in ("site", "_key")})


def snapshot() -> Dict[str, Any]:
    return REGISTRY.snapshot()


def restore(snap: Optional[Dict[str, Any]]) -> None:
    REGISTRY.restore(snap)


def reset() -> None:
    REGISTRY.reset()


__all__ = ["ExecutableRegistry", "REGISTRY", "dispatch", "key_str",
           "profiling", "reset", "restore", "snapshot"]
