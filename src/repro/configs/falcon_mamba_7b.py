"""falcon-mamba-7b [ssm] — pure Mamba-1, attention-free. [arXiv:2410.05355]"""
from repro.configs.base import ArchConfig, LayerSpec, Segment, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    d_model=4096,
    vocab_size=65024,
    segments=(Segment((LayerSpec("ssm", "none"),), 64),),
    d_ff=0,                            # mamba block carries its own channel mix
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, dt_rank=256),
    use_rope=False,
    source="arXiv:2410.05355; unverified",
    notes="sub-quadratic: O(1) recurrent state -> long_500k runs; "
          "paper-technique caveat: A_log/dt params excluded from aggressive "
          "quantization (DESIGN.md §6)",
)
