"""Unit + property tests for quantization (paper §II-A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import quantization as Q


def test_roundtrip_exact_grid():
    w = jnp.asarray([-1.0, -0.5, 0.0, 0.5, 1.0])
    q, s = Q.quantize_int(w, Q.QuantConfig(bits=8))
    np.testing.assert_allclose(np.asarray(Q.dequantize(q, s)), np.asarray(w),
                               atol=1e-2)


def test_levels_bounded():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    for bits in range(2, 9):
        q, _ = Q.quantize_int(w, Q.QuantConfig(bits=bits))
        nlevels = len(np.unique(np.asarray(q)))
        assert nlevels <= 2 ** bits - 1
        assert int(jnp.max(jnp.abs(q))) <= 2 ** (bits - 1) - 1


def test_fake_quant_ste_gradient_is_identity():
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 8))
    g = jax.grad(lambda w: jnp.sum(Q.fake_quant(w, Q.QuantConfig(bits=4))
                                   * 3.0))(w)
    np.testing.assert_allclose(np.asarray(g), 3.0 * np.ones((8, 8)), atol=1e-6)


def test_error_monotone_in_bits():
    w = jax.random.normal(jax.random.PRNGKey(2), (128, 128))
    errs = [Q.quant_error(w, Q.QuantConfig(bits=b)) for b in range(2, 9)]
    assert all(a >= b - 1e-6 for a, b in zip(errs, errs[1:])), errs


def test_per_channel_not_worse():
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 32)) \
        * jnp.linspace(0.1, 10.0, 32)[None, :]
    e_t = Q.quant_error(w, Q.QuantConfig(bits=4, per_channel=False))
    e_c = Q.quant_error(w, Q.QuantConfig(bits=4, per_channel=True))
    assert e_c <= e_t


@settings(max_examples=25, deadline=None)
@given(bits=st.integers(2, 8), seed=st.integers(0, 2 ** 16))
def test_property_fake_quant_idempotent(bits, seed):
    """fq(fq(w)) == fq(w): the grid is a fixpoint."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (16, 16))
    qc = Q.QuantConfig(bits=bits)
    w1 = Q.fake_quant(w, qc)
    w2 = Q.fake_quant(w1, qc)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(bits=st.integers(2, 8), seed=st.integers(0, 2 ** 16))
def test_property_quant_error_bounded(bits, seed):
    """|w - deq(q)| <= scale/2 elementwise (uniform grid guarantee)."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (32,))
    q, s = Q.quantize_int(w, Q.QuantConfig(bits=bits))
    err = np.max(np.abs(np.asarray(w) - np.asarray(Q.dequantize(q, s))))
    assert err <= float(s) / 2 + 1e-6
