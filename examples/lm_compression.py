"""Beyond-paper integration: the paper's hardware-aware minimization applied
to an LM, with the TPU roofline as the hardware cost (DESIGN.md §3).

Trains a tiny qwen3-family LM, then runs the NSGA-II search over per-matmul
(bits, block-sparsity, clusters) where the cost objective is the *decode-step
roofline seconds* from repro.core.tpu_cost and the accuracy objective is eval
loss under the QAT forward. Prints the Pareto front: eval-loss vs projected
decode latency.

Run:  PYTHONPATH=src python examples/lm_compression.py
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core import tpu_cost as TC
from repro.core.compression_spec import LayerMin, ModelMin, qat_weight
from repro.core.ga import GAConfig, run_nsga2
from repro.core import pruning as P
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.nn import transformer as T
from repro.train import losses
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    cfg = ARCHS["qwen3-0.6b"].reduced(vocab_size=512, d_model=128,
                                      num_heads=4, num_kv_heads=2,
                                      head_dim=32, d_ff=512)
    pipe = TokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, branching=4))

    print("pretraining the base LM (~1 min)...")
    opt = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=120)
    tr = Trainer(cfg, opt, TrainerConfig(total_steps=120, log_every=40), pipe)
    out = tr.run()
    params = None
    state, _ = tr.init_or_resume(jax.random.PRNGKey(0))
    # retrain quickly to get trained params in hand
    step = tr.step_fn
    for s in range(120):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
        state, m = step(state, batch)
    params = state.params

    # compressible layer inventory (matmul weights >= 64x64)
    shapes = TC.lm_layer_shapes(params)
    names = sorted(shapes)
    print(f"{len(names)} compressible weight groups")

    eval_batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(9999).items()}

    @functools.lru_cache(maxsize=256)
    def eval_spec(spec_json: str) -> float:
        spec = ModelMin.from_json(spec_json)
        by_name = dict(zip(names, spec.layers))

        def leaf(path, w):
            nm = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                          for k in path)
            if nm in by_name and w.ndim >= 2:
                return qat_weight(w, by_name[nm])
            return w
        qparams = jax.tree_util.tree_map_with_path(leaf, params)
        logits, aux = T.forward(qparams, eval_batch, cfg, remat=False)
        return float(losses.next_token_loss(logits, eval_batch["tokens"],
                                            aux=aux))

    def evaluate(spec: ModelMin):
        loss = eval_spec(spec.to_json())
        cost = TC.spec_cost_seconds([shapes[n] for n in names], spec,
                                    batch_tokens=1)["cost"]
        return (loss, cost * 1e6)          # (eval loss, decode us/token)

    base_spec = ModelMin.uniform(len(names))
    base_loss, base_cost = evaluate(base_spec)
    print(f"bf16 baseline: eval_loss={base_loss:.4f} "
          f"decode={base_cost:.2f} us/token (roofline)")

    res = run_nsga2(len(names), evaluate,
                    GAConfig(population=12, generations=5, seed=0),
                    seed_specs=[base_spec,
                                ModelMin.uniform(len(names), bits=8),
                                ModelMin.uniform(len(names), bits=4)])
    from repro.core.pareto import pareto_front
    front = pareto_front(res.objectives)
    print("pareto front (eval_loss, decode us/token, spec of first layer):")
    order = np.argsort(res.objectives[front][:, 1])
    for i in np.asarray(front)[order][:8]:
        s = res.population[int(i)]
        print(f"  loss={res.objectives[i,0]:.4f} "
              f"decode={res.objectives[i,1]:7.2f}us  "
              f"L0={dataclasses.asdict(s.layers[0])}")
    best = front[np.argmin(res.objectives[front][:, 1])]
    print(f"max projected decode speedup at tolerable loss: "
          f"{base_cost / res.objectives[best,1]:.2f}x")


if __name__ == "__main__":
    main()
