"""Verification-layer tests (repro.verify): the netlist verifier is clean
on every sanctioned producer (compiler, pass pipeline, budget fitter)
across all four datasets' architectures, catches 100% of the seeded
corruption catalog, the spec linter guards the GA genome / EvalCache
keyspace, and the IR edge-case hardening holds."""
import os

import numpy as np
import pytest

from repro import approx, circuit
from repro.approx.budget import ApproxParams
from repro.circuit import ir
from repro.configs.printed_mlp import PRINTED_MLPS
from repro.core.compression_spec import LayerMin, ModelMin
from repro.verify import (CATALOG, ERROR, WARN, Diagnostic,
                          VerificationError, apply_mutation, check_netlist,
                          check_specs, errors, lint_spec, verify_enabled,
                          verify_netlist)

from test_circuit import synth_compiled

DATASET_PARAMS = {
    # modest synthetic stand-ins with each dataset's real layer dims
    "whitewine": dict(sparsity=0.4, clusters=4, seed=11),
    "redwine": dict(sparsity=0.3, clusters=None, seed=12),
    "pendigits": dict(sparsity=0.6, clusters=8, seed=13),
    "seeds": dict(sparsity=0.0, clusters=4, seed=14),
}


def _compiled_net(name):
    cfg = PRINTED_MLPS[name]
    c = synth_compiled(cfg.layer_dims, 4, **DATASET_PARAMS[name])
    return circuit.compile_netlist(c)


# ---------------------------------------------------------------------------
# verifier: clean on sanctioned producers, all four architectures
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(PRINTED_MLPS))
def test_verifier_clean_on_compiled_and_budgeted(name):
    net = _compiled_net(name)
    assert verify_netlist(net, expect_exact=True, expect_dce=True) == []

    # fixed-knob approximation
    L = net.n_layers
    anet = approx.approximate(net, ApproxParams((1,) * L, (2,) * L, 2))
    assert verify_netlist(anet, expect_dce=True) == []

    # budget-fitted approximation (small caps keep the greedy search quick)
    _, bnet, rep = approx.fit_budget(net, approx.logit_budget(net, 0.03),
                                     max_csd_drop=2, max_lsb=4,
                                     max_argmax_lsb=3)
    assert verify_netlist(bnet, expect_dce=True) == []
    assert rep.bound <= rep.budget


# ---------------------------------------------------------------------------
# verifier: 100% detection of the seeded-corruption catalog
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def victim_nets():
    net = _compiled_net("whitewine")
    anet = approx.approximate(
        net, ApproxParams((1, 2), (2, 1), 3))
    return net, anet


@pytest.mark.parametrize("mutation", CATALOG, ids=lambda m: m.name)
def test_mutation_catalog_detected(victim_nets, mutation):
    net, anet = victim_nets
    bad = apply_mutation(anet, mutation) or apply_mutation(net, mutation)
    assert bad is not None, f"{mutation.name} inapplicable to both victims"
    diags = verify_netlist(bad, expect_dce=mutation.needs_dce)
    fatal = {d.rule for d in diags
             if d.severity == ERROR or mutation.strict_only}
    assert fatal & mutation.rules, (
        f"{mutation.name}: expected one of {sorted(mutation.rules)}, "
        f"got {sorted((d.severity, d.rule) for d in diags)}")


def test_mutations_raise_through_check(victim_nets):
    net, anet = victim_nets
    for m in CATALOG:
        bad = apply_mutation(anet, m) or apply_mutation(net, m)
        with pytest.raises((VerificationError, OverflowError)):
            check_netlist(bad, strict=True, expect_dce=m.needs_dce)


def test_width_budget_maps_to_overflowerror():
    # the historical Netlist.validate contract: a pure width violation is
    # an OverflowError, not a VerificationError
    net = ir.Netlist(in_bits=8, w_bits=[4])
    x = net.input(0)
    for _ in range(9):
        x = net.shl(x, 7)
    net.layer_pre_ids.append([x])
    net.output_ids = [x]
    with pytest.raises(OverflowError):
        check_netlist(net)


# ---------------------------------------------------------------------------
# pass-pipeline instrumentation
# ---------------------------------------------------------------------------


def test_pass_manager_catches_lying_pass():
    from repro.approx.rewrite import Pass, PassManager, rebuild

    class Inflate(Pass):
        """Claims monotone cost, then grows every multiplier."""
        name = "inflate"
        monotone_cost = True

        def run(self, net):
            def rw(new, old, n, m):
                if n.op != ir.Op.SHL or n.role != ir.ROLE_MULT:
                    return None
                # x<<s  ->  (x<<s - x<<0) + x<<0: same value, two extra
                # mult-tagged SHL wires (csd_digits) — cost strictly up
                tags = dict(role=n.role, layer=n.layer, unit=n.unit)
                x = m[n.args[0]]
                a = new.shl(x, n.shift, **tags)
                out = new.add(new.sub(a, new.shl(x, 0, **tags), **tags),
                              new.shl(x, 0, **tags), **tags)
                new.nodes[out].product_root = n.product_root
                return out
            return rebuild(net, rw)

    net = _compiled_net("seeds")
    with pytest.raises(VerificationError) as e:
        PassManager([Inflate()], verify=True).run(net)
    assert any(d.rule == "pass-cost" for d in e.value.diagnostics)


def test_pass_manager_catches_bound_loss():
    from repro.approx.rewrite import Pass, PassManager, rebuild

    class DropErr(Pass):
        """Truncates but forgets to declare the error (annotation-less
        TRUNC is structurally declared, so instead it erases an upstream
        pass's annotation)."""
        name = "drop-err"
        monotone_bound = True

        def run(self, net):
            def rw(new, old, n, m):
                return None
            out = rebuild(net, rw)
            for n in out.nodes:
                n.err_lo = n.err_hi = 0
            return out

    net = _compiled_net("seeds")
    anet = approx.approximate(net, ApproxParams((2,), (0,), 0))
    if approx.logit_error_bound(anet) == 0:
        pytest.skip("csd rounding produced no declared error on this net")
    with pytest.raises(VerificationError) as e:
        PassManager([DropErr()], verify=True).run(anet)
    assert any(d.rule == "pass-bound" for d in e.value.diagnostics)


def test_identity_pipeline_verified_is_noop():
    from repro.approx.rewrite import PassManager
    net = _compiled_net("seeds")
    out = PassManager([], verify=True).run(net)
    assert circuit.structural_cost(out).total_fa == pytest.approx(
        circuit.structural_cost(net).total_fa)


def test_verify_enabled_flag(monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY", "0")
    assert not verify_enabled()
    monkeypatch.setenv("REPRO_VERIFY", "1")
    assert verify_enabled()
    assert not verify_enabled(False)       # explicit override wins
    monkeypatch.delenv("REPRO_VERIFY")
    assert not verify_enabled()
    assert verify_enabled(True)


# ---------------------------------------------------------------------------
# spec linter
# ---------------------------------------------------------------------------


def test_spec_lint_clean_on_legal_spec():
    cfg = PRINTED_MLPS["whitewine"]
    s = ModelMin.uniform(len(cfg.layer_dims) - 1, bits=4, sparsity=0.5,
                         clusters=4, csd_drop=1, lsb=2, argmax_lsb=2)
    assert lint_spec(s, cfg) == []


def test_spec_lint_range_violations():
    s = ModelMin((LayerMin(bits=1),), input_bits=8)
    rules = {d.rule for d in errors(lint_spec(s))}
    assert "range" in rules
    s = ModelMin((LayerMin(bits=4, lsb=99),))
    assert any(d.rule == "range" for d in errors(lint_spec(s)))
    assert any(d.rule == "range" for d in errors(lint_spec(ModelMin(()))))


def test_spec_lint_rejects_noncanonical_scalars():
    # np.int64 genes serialize differently under some json encoders and
    # fracture the EvalCache keyspace — caught before any training
    s = ModelMin((LayerMin(bits=np.int64(4)),))
    assert any(d.rule == "type" for d in errors(lint_spec(s)))


def test_spec_lint_arch_rules():
    cfg = PRINTED_MLPS["seeds"]        # dims (7, 8, 3)
    L = len(cfg.layer_dims) - 1
    wrong = ModelMin.uniform(L + 1, bits=4)
    assert any(d.rule == "arch" for d in errors(lint_spec(wrong, cfg)))
    # clusters > layer outputs is degenerate but legal: WARN, never ERROR
    degen = ModelMin.uniform(L, bits=4, clusters=16)
    diags = lint_spec(degen, cfg)
    assert errors(diags) == []
    assert any(d.severity == WARN and d.rule == "arch" for d in diags)


def test_check_specs_raises_and_passes():
    cfg = PRINTED_MLPS["whitewine"]
    L = len(cfg.layer_dims) - 1
    good = [ModelMin.uniform(L, bits=b) for b in (2, 4, 8)]
    check_specs(good, cfg)                       # no raise
    with pytest.raises(VerificationError):
        check_specs(good + [ModelMin.uniform(L, bits=77)], cfg)


# ---------------------------------------------------------------------------
# IR edge-case hardening (PR 6 satellites)
# ---------------------------------------------------------------------------


def test_const_dedup_keeps_canonical_tags():
    net = ir.Netlist(in_bits=8, w_bits=[4])
    a = net.const(5)
    b = net.const(5, role=ir.ROLE_MULT, layer=3, unit=(1, 2))
    assert a == b
    n = net.nodes[a]
    assert (n.role, n.layer, n.unit) == (ir.ROLE_CONST, -1, ())
    # the verifier enforces the canonical-tag convention on shared consts
    assert not [d for d in verify_netlist(net)
                if d.rule in ("const-dedup", "role")]


def test_argmax_guards():
    net = ir.Netlist(in_bits=8, w_bits=[])
    with pytest.raises(ValueError):
        net.argmax([])
    x = net.input(0)
    net.layer_pre_ids.append([x])
    net.output_ids = [x]
    net.argmax([x])
    with pytest.raises(ValueError):
        net.argmax([x])


def test_degenerate_netlist_analyses():
    empty = ir.Netlist(in_bits=8, w_bits=[])
    assert empty.levels() == []
    assert empty.depths() == []
    assert empty.critical_path_levels() == 0

    single = ir.Netlist(in_bits=8, w_bits=[])
    single.input(0)
    assert single.levels() == [[0]]
    assert single.depths() == [0]
    assert single.critical_path_levels() == 0

    # wire-only: SHL adds a level but no gate depth
    wires = ir.Netlist(in_bits=8, w_bits=[])
    x = wires.input(0)
    y = wires.shl(x, 3)
    assert wires.levels() == [[x], [y]]
    assert wires.depths()[y] == 0
    assert wires.critical_path_levels() == 0


def test_validate_delegates_to_verifier():
    net = ir.Netlist(in_bits=8, w_bits=[4])
    x = net.input(0)
    net.layer_pre_ids.append([net.add(x, x, role=ir.ROLE_BIAS, layer=0,
                                      unit=(0,))])
    net.output_ids = list(net.layer_pre_ids[0])
    net.validate()                               # sound, non-strict

    net.nodes[x].lo, net.nodes[x].hi = 5, 3      # corrupt an interval
    with pytest.raises(AssertionError):          # VerificationError is-a
        net.validate()
