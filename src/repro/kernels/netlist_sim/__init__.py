"""Population-batched netlist simulation (see ops.py for the engine map)."""
from repro.kernels.netlist_sim.kernel import netlist_sim_pallas  # noqa: F401
from repro.kernels.netlist_sim.ops import (population_accuracy,  # noqa: F401
                                           simulate_population)
from repro.kernels.netlist_sim.pack import (NOP,  # noqa: F401
                                            PackedNetlist, PackedPopulation,
                                            pack_netlist, pack_population,
                                            unpack_netlist)
from repro.kernels.netlist_sim.ref import \
    simulate_population_ref  # noqa: F401
