"""Mamba-1 selective SSM block (falcon-mamba-7b).

TPU adaptation notes (DESIGN.md §3): the CUDA selective-scan kernel keeps the
recurrent state in SM shared memory and scans time inside the kernel. The
JAX-native equivalent is a ``lax.scan`` over time with the state resident in
VMEM/registers (XLA keeps small carries on-chip); channels/state dims are
fully parallel (VPU lanes). Decode is a single fused state update.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.nn import layers as L


def _dims(cfg: ArchConfig):
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return s, d_inner, dt_rank


def ssm_init(key, cfg: ArchConfig, dtype):
    s, d_inner, dt_rank = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    a = jnp.broadcast_to(jnp.arange(1, s.d_state + 1, dtype=jnp.float32),
                         (d_inner, s.d_state))
    dt_init = jnp.exp(jax.random.uniform(ks[4], (d_inner,), jnp.float32)
                      * (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    return {
        "in_proj": L.dense_init(ks[0], d, 2 * d_inner, dtype),
        "conv": {"kernel": L._trunc_normal(ks[1], (s.d_conv, d_inner),
                                           1.0 / math.sqrt(s.d_conv), dtype),
                 "bias": jnp.zeros((d_inner,), dtype)},
        "x_proj": L.dense_init(ks[2], d_inner, dt_rank + 2 * s.d_state, dtype),
        "dt_proj": {"kernel": L._trunc_normal(ks[3], (dt_rank, d_inner),
                                              dt_rank ** -0.5, jnp.float32),
                    # softplus^-1(dt) bias so initial dt spans [1e-3, 1e-1]
                    "bias": (dt_init + jnp.log(-jnp.expm1(-dt_init)))},
        "A_log": jnp.log(a),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": L.dense_init(ks[5], d_inner, d, dtype),
    }


def _causal_conv(xc, kernel, bias, *, state=None):
    """Depthwise causal conv. xc: (B,T,C); kernel: (K,C). state: (B,K-1,C)."""
    K = kernel.shape[0]
    if state is not None:
        xc = jnp.concatenate([state.astype(xc.dtype), xc], axis=1)
        new_state = xc[:, -(K - 1):]
        pad = 0
    else:
        new_state = xc[:, -(K - 1):]
        pad = K - 1
    y = jax.lax.conv_general_dilated(
        xc, kernel[:, None, :],             # (K, 1, C) depthwise
        window_strides=(1,), padding=[(pad, 0)],
        dimension_numbers=("NTC", "TIO", "NTC"),
        feature_group_count=kernel.shape[1])
    return y + bias, new_state


def _selective_scan(u, dt, B_, C_, A, D):
    """u/dt: (B,T,d); B_/C_: (B,T,N); A: (d,N); D: (d,). Returns y, h_last.

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t u_t ;  y_t = C_t . h_t + D u_t
    scan over T; state (B,d,N) fp32.
    """
    Bsz, T, d = u.shape
    N = A.shape[1]

    def step(h, xs):
        u_t, dt_t, b_t, c_t = xs                          # (B,d) (B,d) (B,N) (B,N)
        da = jnp.exp(dt_t[..., None] * A[None])           # (B,d,N)
        h = da * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t) + D[None] * u_t
        return h, y

    xs = (u.transpose(1, 0, 2).astype(jnp.float32),
          dt.transpose(1, 0, 2),
          B_.transpose(1, 0, 2).astype(jnp.float32),
          C_.transpose(1, 0, 2).astype(jnp.float32))
    h0 = jnp.zeros((Bsz, d, N), jnp.float32)
    h_last, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2), h_last


def ssm_apply(p, x, cfg: ArchConfig, *, cache=None):
    """Mamba-1 block. x: (B,T,d). Returns (out, new_cache).

    cache (decode): {"conv": (B, K-1, d_inner), "h": (B, d_inner, N)}.
    """
    s, d_inner, dt_rank = _dims(cfg)
    xz = L.dense_apply(p["in_proj"], x)
    xc, z = jnp.split(xz, 2, axis=-1)

    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = _causal_conv(xc, p["conv"]["kernel"], p["conv"]["bias"],
                                state=conv_state)
    xc = jax.nn.silu(xc)

    proj = L.dense_apply(p["x_proj"], xc)
    dt_raw = proj[..., :dt_rank]
    B_ = proj[..., dt_rank:dt_rank + s.d_state]
    C_ = proj[..., dt_rank + s.d_state:]
    dt = jax.nn.softplus(
        jnp.einsum("btr,rd->btd", dt_raw.astype(jnp.float32),
                   p["dt_proj"]["kernel"]) + p["dt_proj"]["bias"])
    A = -jnp.exp(p["A_log"])

    if cache is not None and x.shape[1] == 1:
        # single-step decode: one state update, no scan
        h = cache["h"]
        da = jnp.exp(dt[:, 0, :, None] * A[None])
        h = da * h + (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] \
            * B_[:, 0, None, :].astype(jnp.float32)
        y = jnp.einsum("bdn,bn->bd", h, C_[:, 0].astype(jnp.float32)) \
            + p["D"][None] * xc[:, 0].astype(jnp.float32)
        y = y[:, None]
        new_cache = {"conv": new_conv, "h": h}
    else:
        y, h_last = _selective_scan(xc, dt, B_, C_, A, p["D"])
        new_cache = None if cache is None else {"conv": new_conv, "h": h_last}

    y = y.astype(x.dtype) * jax.nn.silu(z)
    return L.dense_apply(p["out_proj"], y), new_cache


def make_ssm_cache(cfg: ArchConfig, batch: int, dtype):
    s, d_inner, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, d_inner), dtype),
        "h": jnp.zeros((batch, d_inner, s.d_state), jnp.float32),
    }
